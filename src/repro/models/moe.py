"""Mixture-of-Experts layers + MoE decoder models.

Covers both assigned MoE archs:
  * mixtral-8x22b  — GQA attention (SWA 4096) + 8 routed experts, top-2
  * deepseek-v2-lite — MLA attention + (2 shared + 64 routed, top-6) experts,
    first layer dense (arXiv:2405.04434)

Routing uses the MaxText/Mesh-TF style *dropping* dispatch: tokens are
reshaped into groups, and within each group a capacity-bounded one-hot
dispatch/combine einsum moves tokens to experts.  This is fully static-shaped
(TPU-friendly) and lets the compiler lay down all-to-all / all-gather
collectives when experts are sharded over the "model" mesh axis.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla
from repro.models.attention import KVCache
from repro.models.common import (ModelConfig, Params, dense_apply, dense_param,
                                 embed_apply, init_embed, init_mlp, init_rms,
                                 mlp_apply, normal_init, rms_norm, scan_layers,
                                 stack_layers, unembed_apply)

MOE_GROUP = 1024  # dispatch group size (tokens); decode uses one group


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------
def init_moe_layer(key, cfg: ModelConfig) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    E, dff = cfg.n_experts, cfg.d_ff_expert

    def one_expert(k):
        return init_mlp(k, cfg.d_model, dff, cfg.dtype)

    p = {
        "router": normal_init(kr, (cfg.d_model, E), jnp.float32),
        "experts": stack_layers(one_expert, ke, E),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks, cfg.d_model, dff * cfg.n_shared_experts, cfg.dtype)
    return p


def _dispatch(probs: jnp.ndarray, top_k: int, capacity: int):
    """probs (g,E) -> (dispatch (g,E,C) bool-ish, combine (g,E,C) float)."""
    g, E = probs.shape
    top_p, top_i = jax.lax.top_k(probs, top_k)  # (g,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (g,K,E)
    # priority: earlier tokens first, k-slots of one token in order
    flat = onehot.reshape(g * top_k, E)
    pos = jnp.cumsum(flat, axis=0) - flat  # (gK,E) position within expert
    keep = (pos < capacity) * flat
    disp_slot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * keep[..., None]
    disp = disp_slot.reshape(g, top_k, E, capacity)
    dispatch = disp.sum(1)  # (g,E,C)
    combine = jnp.einsum("gkec,gk->gec", disp, top_p)
    return dispatch, combine


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,T,d) -> (y (B,T,d), aux_loss scalar)."""
    B, T, d = x.shape
    g_total = B * T
    xf = x.reshape(g_total, d)
    group = min(MOE_GROUP, g_total)
    pad = (-g_total) % group
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_groups = xf.shape[0] // group
    xg = xf.reshape(n_groups, group, d)

    logits = (xg.astype(jnp.float32) @ p["router"])  # (n,gr,E)
    probs = jax.nn.softmax(logits, axis=-1)
    E = cfg.n_experts
    cap = max(1, int(group * cfg.top_k * cfg.capacity_factor / E))

    def one_group(args):
        xg_g, probs_g = args  # (gr,d), (gr,E)
        dispatch, combine = _dispatch(probs_g, cfg.top_k, cap)
        xe = jnp.einsum("gec,gd->ecd", dispatch.astype(x.dtype), xg_g)

        def run_expert(ep, xe_e):  # xe_e (C,d)
            return mlp_apply(ep, xe_e, cfg.act)

        he = jax.vmap(run_expert)(p["experts"], xe)  # (E,C,d)
        y_g = jnp.einsum("gec,ecd->gd", combine.astype(x.dtype), he)
        frac_g = dispatch.sum(axis=(0, 2)) / (group * cfg.top_k)  # (E,)
        return y_g, frac_g

    if n_groups == 1:
        y, frac = one_group((xg[0], probs[0]))
        y, frac = y[None], frac[None]
    else:
        # sequential over groups: bounds live dispatch/einsum memory to one
        # group regardless of token count (1M tokens at train_4k)
        y, frac = jax.lax.map(one_group, (xg, probs))
    y = y.reshape(-1, d)
    if pad:
        y = y[:g_total]
    y = y.reshape(B, T, d)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg.act)
    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    imp = probs.mean(axis=1)  # (n,E)
    aux = E * jnp.mean(jnp.sum(frac * imp, axis=-1))
    return y, aux


# ---------------------------------------------------------------------------
# MoE decoder model (mixtral path: GQA; deepseek path: MLA)
# ---------------------------------------------------------------------------
class MoECache(NamedTuple):
    kv: object  # KVCache (GQA) or mla.MLACache
    dense_kv: object  # same type, for the first dense layers (or None-like)


def _init_block(key, cfg: ModelConfig, moe: bool) -> Params:
    ka, km = jax.random.split(key)
    p = {
        "attn": (mla.init_mla(ka, cfg) if cfg.use_mla else attn.init_attention(ka, cfg)),
        "ln_attn": init_rms(cfg.d_model, cfg.dtype),
        "ln_mlp": init_rms(cfg.d_model, cfg.dtype),
    }
    if moe:
        p["moe"] = init_moe_layer(km, cfg)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init(key, cfg: ModelConfig) -> Params:
    ke, kd, kl = jax.random.split(key, 3)
    nf = cfg.first_dense_layers
    params = {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": stack_layers(lambda k: _init_block(k, cfg, True), kl, cfg.n_layers - nf),
        "ln_f": init_rms(cfg.d_model, cfg.dtype),
    }
    if nf:
        params["dense_layers"] = stack_layers(lambda k: _init_block(k, cfg, False), kd, nf)
    return params


def _attn_fwd(layer, x, positions, cfg, window, mask):
    if cfg.use_mla:
        return mla.mla_forward(layer["attn"], x, positions, cfg, window, mask)
    return attn.attention_forward(layer["attn"], x, positions, cfg, window, mask)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: Optional[jnp.ndarray] = None,
            window: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss)."""
    window = window if window is not None else cfg.sliding_window
    B, T = tokens.shape
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    from repro.models.transformer import make_positions
    positions = make_positions(tokens, lengths)
    mask = (None if T >= attn.CHUNK_THRESHOLD
            else attn.prefill_mask(positions, window))
    h = embed_apply(params["embed"], tokens, cfg)

    def dense_body(carry, layer):
        a = _attn_fwd(layer, rms_norm(carry, layer["ln_attn"], cfg.norm_eps),
                      positions, cfg, window, mask)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, None

    def moe_body(carry, layer):
        a = _attn_fwd(layer, rms_norm(carry, layer["ln_attn"], cfg.norm_eps),
                      positions, cfg, window, mask)
        h2 = carry + a
        m, aux = moe_apply(layer["moe"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg)
        return h2 + m, aux

    if cfg.first_dense_layers:
        h, _ = scan_layers(dense_body, h, params["dense_layers"], remat=cfg.remat)
    h, auxs = scan_layers(moe_body, h, params["layers"], remat=cfg.remat)
    logits = unembed_apply(params["embed"], rms_norm(h, params["ln_f"], cfg.norm_eps))
    return logits, jnp.mean(auxs)


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: jnp.ndarray, cache_window: int,
            window: Optional[int] = None) -> Tuple[jnp.ndarray, MoECache]:
    window = window if window is not None else cfg.sliding_window
    from repro.models.transformer import make_positions
    positions = make_positions(tokens, lengths)
    T = positions.shape[1]
    mask = (None if T >= attn.CHUNK_THRESHOLD
            else attn.prefill_mask(positions, window))
    h = embed_apply(params["embed"], tokens, cfg)
    # SWA archs only ever need `window` ring slots; full attention needs L_i+S
    Wc = cache_window if window is None else min(cache_window, window)

    def body(moe: bool):
        def go(carry, layer):
            x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
            if cfg.use_mla:
                a, c1, c2 = mla.mla_prefill(layer["attn"], x, positions, cfg,
                                            window, Wc, mask)
            else:
                a, c1, c2 = attn.attention_prefill(layer["attn"], x, positions,
                                                   cfg, window, Wc, mask=mask)
            h2 = carry + a
            xm = rms_norm(h2, layer["ln_mlp"], cfg.norm_eps)
            if moe:
                m, _ = moe_apply(layer["moe"], xm, cfg)
            else:
                m = mlp_apply(layer["mlp"], xm, cfg.act)
            return h2 + m, (c1, c2)
        return go

    if cfg.first_dense_layers:
        h, (dk, dv) = scan_layers(body(False), h, params["dense_layers"])
    h, (k_all, v_all) = scan_layers(body(True), h, params["layers"])
    logits = unembed_apply(params["embed"],
                           rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps))[:, 0]

    def mk_cache(k, v):
        common = dict(
            slot_pos=attn.prefill_slot_pos(positions, Wc),
            write_idx=jnp.asarray(T if Wc >= T else Wc, jnp.int32),
            lengths=lengths.astype(jnp.int32))
        if cfg.use_mla:
            return mla.MLACache(ckv=k, kr=v, **common)
        return KVCache(k=k, v=v, **common)

    dense_cache = mk_cache(dk, dv) if cfg.first_dense_layers else None
    return logits, MoECache(kv=mk_cache(k_all, v_all), dense_kv=dense_cache)


def decode_step(params: Params, cfg: ModelConfig, cache: MoECache,
                tokens: jnp.ndarray, step: jnp.ndarray,
                window: Optional[int] = None) -> Tuple[jnp.ndarray, MoECache]:
    window = window if window is not None else cfg.sliding_window
    kvc = cache.kv
    q_pos = kvc.lengths + step
    slot = attn.decode_slot(kvc) if not cfg.use_mla else mla.decode_slot(kvc)
    slot_pos = (attn.decode_slot_pos(kvc, q_pos) if not cfg.use_mla
                else mla.decode_slot_pos(kvc, q_pos))
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def body(moe: bool):
        def go(carry, layer, c1, c2):
            x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
            if cfg.use_mla:
                a, c1, c2 = mla.mla_decode(layer["attn"], x, q_pos, c1, c2,
                                           slot_pos, slot, cfg, window)
            else:
                a, c1, c2 = attn.attention_decode(layer["attn"], x, q_pos, c1, c2,
                                                  slot_pos, slot, cfg, window)
            h2 = carry + a
            xm = rms_norm(h2, layer["ln_mlp"], cfg.norm_eps)
            if moe:
                m, _ = moe_apply(layer["moe"], xm, cfg)
            else:
                m = mlp_apply(layer["mlp"], xm, cfg.act)
            return h2 + m, (c1, c2)
        return go

    if cfg.first_dense_layers:
        dc = cache.dense_kv
        d1, d2 = (dc.ckv, dc.kr) if cfg.use_mla else (dc.k, dc.v)
        h, (nd1, nd2) = scan_layers(body(False), h, params["dense_layers"], d1, d2)
        if cfg.use_mla:
            new_dense = dc._replace(ckv=nd1, kr=nd2, slot_pos=slot_pos,
                                    write_idx=dc.write_idx + 1)
        else:
            new_dense = dc._replace(k=nd1, v=nd2, slot_pos=slot_pos,
                                    write_idx=dc.write_idx + 1)
    else:
        new_dense = cache.dense_kv

    c1, c2 = (kvc.ckv, kvc.kr) if cfg.use_mla else (kvc.k, kvc.v)
    h, (n1, n2) = scan_layers(body(True), h, params["layers"], c1, c2)
    logits = unembed_apply(params["embed"],
                           rms_norm(h, params["ln_f"], cfg.norm_eps))[:, 0]
    if cfg.use_mla:
        new_kv = kvc._replace(ckv=n1, kr=n2, slot_pos=slot_pos,
                              write_idx=kvc.write_idx + 1)
    else:
        new_kv = kvc._replace(k=n1, v=n2, slot_pos=slot_pos,
                              write_idx=kvc.write_idx + 1)
    return logits, MoECache(kv=new_kv, dense_kv=new_dense)
