"""Decoder-only transformer LM (dense archs + PaliGemma-style prefix-VLM).

API (used by the engine, the trainer, and the dry-run):
  init(key, cfg)                                   -> params
  forward(params, cfg, tokens, positions, ...)     -> logits (B,T,V)
  prefill(params, cfg, tokens, lengths, ...)       -> (last_logits, KVCache)
  decode_step(params, cfg, cache, tokens)          -> (logits, KVCache)

Layers are stacked and consumed with lax.scan (HLO is O(1) in depth).
Left-padding convention: ``positions[b, t] < 0`` marks pad tokens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.attention import KVCache
from repro.kvcache.paged import PagedKVCache
from repro.models.common import (ModelConfig, Params, embed_apply, init_embed,
                                 init_mlp, init_rms, mlp_apply, rms_norm,
                                 scan_layers, stack_layers, unembed_apply,
                                 dense_param, dense_apply)


def init_block(key, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(key)
    return {
        "attn": attn.init_attention(ka, cfg),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype),
        "ln_attn": init_rms(cfg.d_model, cfg.dtype),
        "ln_mlp": init_rms(cfg.d_model, cfg.dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    ke, kl, ku = jax.random.split(key, 3)
    params = {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": stack_layers(lambda k: init_block(k, cfg), kl, cfg.n_layers),
        "ln_f": init_rms(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_param(ku, cfg.d_model, cfg.vocab_size, cfg.dtype)
    return params


def _logits(params: Params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed_apply(params["embed"], h)
    return dense_apply(params["unembed"], h)


def _block_fwd(layer: Params, h, positions, cfg, window, mask, prefix_len=0):
    a = attn.attention_forward(layer["attn"], rms_norm(h, layer["ln_attn"], cfg.norm_eps),
                               positions, cfg, window, mask, prefix_len=prefix_len)
    h = h + a
    m = mlp_apply(layer["mlp"], rms_norm(h, layer["ln_mlp"], cfg.norm_eps), cfg.act)
    return h + m


def make_positions(tokens: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
    """Left-padded position ids: pads get -1, real tokens 0..len-1."""
    B, T = tokens.shape
    idx = jnp.arange(T)[None]
    return jnp.where(idx < T - lengths[:, None], -1, idx - (T - lengths[:, None]))


def _mask_with_prefix(positions: jnp.ndarray, window: Optional[int],
                      prefix_len: int) -> jnp.ndarray:
    m = attn.prefill_mask(positions, window)
    if prefix_len:
        pk = positions[:, None, :]
        pq = positions[:, :, None]
        bidir = (pk >= 0) & (pk < prefix_len) & (pq >= 0)
        m = m | bidir[:, None]
    return m


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None,
            prefix_embeds: Optional[jnp.ndarray] = None,
            window: Optional[int] = None) -> jnp.ndarray:
    """Full-sequence forward (training).  For VLM, ``prefix_embeds``
    (B,P,d) is prepended and ``tokens`` covers only the text part."""
    window = window if window is not None else cfg.sliding_window
    h = embed_apply(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, T, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    big = T >= attn.CHUNK_THRESHOLD
    mask = None if big else _mask_with_prefix(positions, window, cfg.n_prefix_tokens)

    def body(carry, layer):
        return _block_fwd(layer, carry, positions, cfg, window, mask,
                          cfg.n_prefix_tokens), None

    h, _ = scan_layers(body, h, params["layers"], remat=cfg.remat)
    return _logits(params, cfg, h)


# ---------------------------------------------------------------------------
# serving path
# ---------------------------------------------------------------------------
def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: jnp.ndarray, cache_window: int,
            prefix_embeds: Optional[jnp.ndarray] = None,
            window: Optional[int] = None) -> Tuple[jnp.ndarray, KVCache]:
    """Run the prefill phase and build the KV cache (width ``cache_window``)."""
    window = window if window is not None else cfg.sliding_window
    positions = make_positions(tokens, lengths)
    h = embed_apply(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(P)[None], (h.shape[0], P)),
             jnp.where(positions >= 0, positions + P, -1)], axis=1)
        lengths = lengths + P
    B, T = positions.shape
    big = T >= attn.CHUNK_THRESHOLD
    mask = None if big else _mask_with_prefix(positions, window, cfg.n_prefix_tokens)

    def body(carry, layer):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a, kc, vc = attn.attention_prefill(layer["attn"], x, positions, cfg,
                                           window, cache_window, mask=mask,
                                           prefix_len=cfg.n_prefix_tokens)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, (kc, vc)

    h, (k_all, v_all) = scan_layers(body, h, params["layers"])
    logits = _logits(params, cfg, h[:, -1:, :])
    cache = KVCache(
        k=k_all, v=v_all,
        slot_pos=attn.prefill_slot_pos(positions, cache_window),
        write_idx=jnp.asarray(T if cache_window >= T else cache_window, jnp.int32),
        lengths=lengths.astype(jnp.int32),
    )
    return logits[:, 0], cache


def prefill_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  lengths: jnp.ndarray, cache: PagedKVCache,
                  window: Optional[int] = None, attn_impl: str = "unfused"
                  ) -> Tuple[jnp.ndarray, PagedKVCache]:
    """Prefill that writes K/V straight into the paged pool.

    ``attn_impl="fused"`` selects the fused RoPE+page-write kernel path
    (``attention.attention_prefill_paged``); ``"unfused"`` (default) is
    the correctness baseline.

    The paged twin of ``prefill``: same left-padded attention math, but
    per-layer K/V land in ``cache.k_pages``/``v_pages`` through the
    per-row block tables (``attention.attention_prefill_paged`` →
    ``kernels.ops.paged_prefill_write``) instead of a transient dense
    (B, W) buffer — so prefix KV survives the slice boundary and a
    resumed slice never re-prefills (``engine.static_engine``, paper
    §3.3).  Layout: logical slot == absolute position (no pad slots);
    ``slot_pos``/``lengths`` of the prefilled rows are refreshed
    accordingly.  Token-only dense archs (no ``prefix_embeds``).
    """
    window = window if window is not None else cfg.sliding_window
    positions = make_positions(tokens, lengths)
    h = embed_apply(params["embed"], tokens, cfg)
    B, T = positions.shape
    big = T >= attn.CHUNK_THRESHOLD
    mask = None if big else attn.prefill_mask(positions, window)

    def body(carry, layer, kp, vp):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a, kp, vp = attn.attention_prefill_paged(
            layer["attn"], x, positions, cfg, window, kp, vp,
            cache.block_table, mask=mask, impl=attn_impl)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, (kp, vp)

    h, (k_all, v_all) = scan_layers(body, h, params["layers"],
                                    cache.k_pages, cache.v_pages)
    logits = _logits(params, cfg, h[:, -1:, :])
    W = cache.window
    slots = jnp.arange(W, dtype=jnp.int32)[None]
    slot_pos = jnp.where(slots < lengths[:, None], slots, -1)
    return logits[:, 0], cache._replace(k_pages=k_all, v_pages=v_all,
                                        slot_pos=slot_pos,
                                        lengths=lengths.astype(jnp.int32))


def prefill_tail_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                       start: jnp.ndarray, lengths: jnp.ndarray,
                       cache: PagedKVCache, window: Optional[int] = None,
                       attn_impl: str = "unfused"
                       ) -> Tuple[jnp.ndarray, PagedKVCache]:
    """Prefill only the novel *tail* of rows whose prefix KV is resident.

    The cross-request prefix-sharing twin of ``prefill_paged``: each row's
    leading ``start[b]`` tokens already live in pages reachable through
    ``cache.block_table`` (shared or retained from another request), so
    ``tokens`` holds only the left-padded tail and the per-token work
    drops from O(total) to O(tail).  Tail K/V is written at absolute
    slots ``start..lengths-1`` (compact layout, slot == position) and the
    tail queries attend to the full gathered window — see
    ``attention.attention_prefill_tail_paged``.  Returns the next-token
    logits of each row's last tail token and the refreshed cache
    (``slot_pos``/``lengths`` cover the full logical stream).
    """
    window = window if window is not None else cfg.sliding_window
    tail = lengths - start
    base = make_positions(tokens, tail)
    positions = jnp.where(base >= 0, base + start[:, None], -1)
    h = embed_apply(params["embed"], tokens, cfg)
    W = cache.window
    slots = jnp.arange(W, dtype=jnp.int32)[None]
    slot_pos = jnp.where(slots < lengths[:, None], slots, -1)

    def body(carry, layer, kp, vp):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a, kp, vp = attn.attention_prefill_tail_paged(
            layer["attn"], x, positions, cfg, window, kp, vp,
            cache.block_table, slot_pos, impl=attn_impl)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, (kp, vp)

    h, (k_all, v_all) = scan_layers(body, h, params["layers"],
                                    cache.k_pages, cache.v_pages)
    logits = _logits(params, cfg, h[:, -1:, :])
    return logits[:, 0], cache._replace(k_pages=k_all, v_pages=v_all,
                                        slot_pos=slot_pos,
                                        lengths=lengths.astype(jnp.int32))


def decode_step(params: Params, cfg: ModelConfig, cache: KVCache,
                tokens: jnp.ndarray, step: jnp.ndarray,
                window: Optional[int] = None) -> Tuple[jnp.ndarray, KVCache]:
    """One decode iteration. tokens (B,) int32; step () int32 (0-based)."""
    window = window if window is not None else cfg.sliding_window
    q_pos = cache.lengths + step  # (B,)
    slot = attn.decode_slot(cache)
    slot_pos = attn.decode_slot_pos(cache, q_pos)
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def body(carry, layer, kc, vc):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a, kc, vc = attn.attention_decode(layer["attn"], x, q_pos, kc, vc,
                                          slot_pos, slot, cfg, window)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, (kc, vc)

    h, (k_all, v_all) = scan_layers(body, h, params["layers"], cache.k, cache.v)
    logits = _logits(params, cfg, h)[:, 0]
    return logits, cache._replace(k=k_all, v=v_all, slot_pos=slot_pos,
                                  write_idx=cache.write_idx + 1)


def decode_step_rowslots(params: Params, cfg: ModelConfig, cache: KVCache,
                         tokens: jnp.ndarray, q_pos: jnp.ndarray,
                         slots: jnp.ndarray, window: Optional[int] = None
                         ) -> Tuple[jnp.ndarray, KVCache]:
    """Continuous-batching decode: per-row positions/write slots.

    ``q_pos``/``slots`` (B,) — caller (ContinuousEngine) tracks per-slot
    progress.  ``slot_pos`` rows are updated via scatter."""
    window = window if window is not None else cfg.sliding_window
    W = cache.window
    oh = jax.nn.one_hot(slots, W, dtype=jnp.int32)
    slot_pos = cache.slot_pos * (1 - oh) + q_pos[:, None].astype(jnp.int32) * oh
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def body(carry, layer, kc, vc):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a, kc, vc = attn.attention_decode_rowslots(
            layer["attn"], x, q_pos, kc, vc, slot_pos, slots, cfg, window)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, (kc, vc)

    h, (k_all, v_all) = scan_layers(body, h, params["layers"], cache.k, cache.v)
    logits = _logits(params, cfg, h)[:, 0]
    return logits, cache._replace(k=k_all, v=v_all, slot_pos=slot_pos)


def decode_step_paged(params: Params, cfg: ModelConfig, cache: PagedKVCache,
                      tokens: jnp.ndarray, q_pos: jnp.ndarray,
                      slots: jnp.ndarray, window: Optional[int] = None,
                      attn_impl: str = "unfused"
                      ) -> Tuple[jnp.ndarray, PagedKVCache]:
    """Continuous-batching decode over the paged cache (``repro.kvcache``).

    Mirrors ``decode_step_rowslots`` — per-row positions and write slots —
    but K/V live in a shared page pool reached through per-row block
    tables, so a row only occupies the pages its ``(L_i + S)`` envelope
    reserved.  ``slots`` index *logical* row slots; the page indirection
    happens inside the attention layer.
    """
    window = window if window is not None else cfg.sliding_window
    W = cache.window
    oh = jax.nn.one_hot(slots, W, dtype=jnp.int32)
    slot_pos = cache.slot_pos * (1 - oh) + q_pos[:, None].astype(jnp.int32) * oh
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def body(carry, layer, kp, vp):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a, kp, vp = attn.attention_decode_paged(
            layer["attn"], x, q_pos, kp, vp, cache.block_table, slot_pos,
            slots, cfg, window, impl=attn_impl)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, (kp, vp)

    h, (k_all, v_all) = scan_layers(body, h, params["layers"],
                                    cache.k_pages, cache.v_pages)
    logits = _logits(params, cfg, h)[:, 0]
    return logits, cache._replace(k_pages=k_all, v_pages=v_all,
                                  slot_pos=slot_pos)
