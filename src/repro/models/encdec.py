"""Encoder-decoder transformer (SeamlessM4T-v2 text/audio backbone).

The audio frontend (mel-spectrogram + conv feature extractor) is a stub per
the assignment carve-out: the encoder consumes pre-computed frame embeddings
``src_embeds`` (B, T_src, d_model) delivered by ``input_specs``.  The decoder
is a causal transformer with cross-attention; SCLS slices schedule decoder
iterations, and each re-schedule re-runs the encoder (the enc-dec analogue of
prefill re-computation, DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.attention import KVCache
from repro.models.common import (ModelConfig, Params, apply_rope, dense_apply,
                                 dense_param, embed_apply, init_embed,
                                 init_mlp, init_rms, mlp_apply, rms_norm,
                                 scan_layers, stack_layers, unembed_apply)


class EncDecCache(NamedTuple):
    self_cache: KVCache
    cross_k: jnp.ndarray  # (L, B, S_src, Hkv, D)
    cross_v: jnp.ndarray
    src_valid: jnp.ndarray  # (B, S_src) bool


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_enc_block(key, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(key)
    return {
        "attn": attn.init_attention(ka, cfg),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype),
        "ln_attn": init_rms(cfg.d_model, cfg.dtype),
        "ln_mlp": init_rms(cfg.d_model, cfg.dtype),
    }


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    return {
        "self_attn": attn.init_attention(ka, cfg),
        "cross_attn": attn.init_attention(kc, cfg),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype),
        "ln_self": init_rms(cfg.d_model, cfg.dtype),
        "ln_cross": init_rms(cfg.d_model, cfg.dtype),
        "ln_mlp": init_rms(cfg.d_model, cfg.dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    ke, kd, kt, kn = jax.random.split(key, 4)
    return {
        "embed": init_embed(kt, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "enc_layers": stack_layers(lambda k: _init_enc_block(k, cfg), ke, cfg.n_enc_layers),
        "dec_layers": stack_layers(lambda k: _init_dec_block(k, cfg), kd, cfg.n_dec_layers),
        "ln_enc": init_rms(cfg.d_model, cfg.dtype),
        "ln_f": init_rms(cfg.d_model, cfg.dtype),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(params: Params, cfg: ModelConfig, src_embeds: jnp.ndarray,
           src_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    B, S, _ = src_embeds.shape
    if src_valid is None:
        src_valid = jnp.ones((B, S), bool)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    # bidirectional mask restricted to valid source frames
    mask = (None if S >= attn.CHUNK_THRESHOLD
            else (src_valid[:, None, :] & src_valid[:, :, None])[:, None])
    h = src_embeds.astype(cfg.dtype)

    def body(carry, layer):
        x = rms_norm(carry, layer["ln_attn"], cfg.norm_eps)
        a = attn.attention_forward(layer["attn"], x, positions, cfg, None, mask,
                                   valid=src_valid)
        h2 = carry + a
        m = mlp_apply(layer["mlp"], rms_norm(h2, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h2 + m, None

    h, _ = scan_layers(body, h, params["enc_layers"], remat=cfg.remat)
    return rms_norm(h, params["ln_enc"], cfg.norm_eps)


def _cross_kv(layer: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    B, S, _ = enc_out.shape
    k = dense_apply(layer["cross_attn"]["wk"], enc_out).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(layer["cross_attn"]["wv"], enc_out).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _cross_attend(layer: Params, x: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                  src_valid: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, T, _ = x.shape
    q = dense_apply(layer["cross_attn"]["wq"], x).reshape(B, T, cfg.n_heads, cfg.head_dim)
    if T >= attn.CHUNK_THRESHOLD:
        zeros = jnp.zeros((B, T), jnp.int32)
        o = attn.gqa_attend_chunked(q, ck, cv, cfg.head_dim ** -0.5, zeros,
                                    zeros[:, :ck.shape[1]], None,
                                    valid_k=src_valid)
    else:
        mask = jnp.broadcast_to(src_valid[:, None, None, :],
                                (B, 1, T, src_valid.shape[1]))
        o = attn.gqa_attend(q, ck, cv, mask, cfg.head_dim ** -0.5)
    return dense_apply(layer["cross_attn"]["wo"], o.reshape(B, T, -1))


# ---------------------------------------------------------------------------
# decoder — train / prefill / decode
# ---------------------------------------------------------------------------
def forward(params: Params, cfg: ModelConfig, src_embeds: jnp.ndarray,
            tokens: jnp.ndarray, src_valid: Optional[jnp.ndarray] = None
            ) -> jnp.ndarray:
    """Training forward: (B,S,d) source embeds + (B,T) target tokens -> logits."""
    enc_out = encode(params, cfg, src_embeds, src_valid)
    B, S, _ = enc_out.shape
    if src_valid is None:
        src_valid = jnp.ones((B, S), bool)
    T = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    mask = (None if T >= attn.CHUNK_THRESHOLD
            else attn.prefill_mask(positions, None))
    h = embed_apply(params["embed"], tokens, cfg)

    def body(carry, layer):
        x = rms_norm(carry, layer["ln_self"], cfg.norm_eps)
        a = attn.attention_forward(layer["self_attn"], x, positions, cfg, None, mask)
        h2 = carry + a
        ck, cv = _cross_kv(layer, enc_out, cfg)
        c = _cross_attend(layer, rms_norm(h2, layer["ln_cross"], cfg.norm_eps),
                          ck, cv, src_valid, cfg)
        h3 = h2 + c
        m = mlp_apply(layer["mlp"], rms_norm(h3, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h3 + m, None

    h, _ = scan_layers(body, h, params["dec_layers"], remat=cfg.remat)
    return unembed_apply(params["embed"], rms_norm(h, params["ln_f"], cfg.norm_eps))


def prefill(params: Params, cfg: ModelConfig, src_embeds: jnp.ndarray,
            tokens: jnp.ndarray, lengths: jnp.ndarray, cache_window: int,
            src_valid: Optional[jnp.ndarray] = None,
            window: Optional[int] = None) -> Tuple[jnp.ndarray, EncDecCache]:
    window = window if window is not None else cfg.sliding_window
    enc_out = encode(params, cfg, src_embeds, src_valid)
    B, S, _ = enc_out.shape
    if src_valid is None:
        src_valid = jnp.ones((B, S), bool)
    from repro.models.transformer import make_positions
    positions = make_positions(tokens, lengths)
    T = positions.shape[1]
    mask = (None if T >= attn.CHUNK_THRESHOLD
            else attn.prefill_mask(positions, window))
    h = embed_apply(params["embed"], tokens, cfg)

    def body(carry, layer):
        x = rms_norm(carry, layer["ln_self"], cfg.norm_eps)
        a, kc, vc = attn.attention_prefill(layer["self_attn"], x, positions, cfg,
                                           window, cache_window, mask=mask)
        h2 = carry + a
        ck, cv = _cross_kv(layer, enc_out, cfg)
        c = _cross_attend(layer, rms_norm(h2, layer["ln_cross"], cfg.norm_eps),
                          ck, cv, src_valid, cfg)
        h3 = h2 + c
        m = mlp_apply(layer["mlp"], rms_norm(h3, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h3 + m, (kc, vc, ck, cv)

    h, (k_all, v_all, ck_all, cv_all) = scan_layers(body, h, params["dec_layers"])
    logits = unembed_apply(params["embed"], rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps))
    self_cache = KVCache(
        k=k_all, v=v_all,
        slot_pos=attn.prefill_slot_pos(positions, cache_window),
        write_idx=jnp.asarray(T if cache_window >= T else cache_window, jnp.int32),
        lengths=lengths.astype(jnp.int32),
    )
    return logits[:, 0], EncDecCache(self_cache, ck_all, cv_all, src_valid)


def decode_step(params: Params, cfg: ModelConfig, cache: EncDecCache,
                tokens: jnp.ndarray, step: jnp.ndarray,
                window: Optional[int] = None) -> Tuple[jnp.ndarray, EncDecCache]:
    window = window if window is not None else cfg.sliding_window
    sc = cache.self_cache
    q_pos = sc.lengths + step
    slot = attn.decode_slot(sc)
    slot_pos = attn.decode_slot_pos(sc, q_pos)
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def body(carry, layer, kc, vc, ck, cv):
        x = rms_norm(carry, layer["ln_self"], cfg.norm_eps)
        a, kc, vc = attn.attention_decode(layer["self_attn"], x, q_pos, kc, vc,
                                          slot_pos, slot, cfg, window)
        h2 = carry + a
        c = _cross_attend(layer, rms_norm(h2, layer["ln_cross"], cfg.norm_eps),
                          ck, cv, cache.src_valid, cfg)
        h3 = h2 + c
        m = mlp_apply(layer["mlp"], rms_norm(h3, layer["ln_mlp"], cfg.norm_eps), cfg.act)
        return h3 + m, (kc, vc)

    h, (k_all, v_all) = scan_layers(body, h, params["dec_layers"], sc.k, sc.v,
                                    cache.cross_k, cache.cross_v)
    logits = unembed_apply(params["embed"], rms_norm(h, params["ln_f"], cfg.norm_eps))[:, 0]
    new_self = sc._replace(k=k_all, v=v_all, slot_pos=slot_pos,
                           write_idx=sc.write_idx + 1)
    return logits, cache._replace(self_cache=new_self)
