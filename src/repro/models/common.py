"""Shared building blocks for the model zoo.

Everything is raw JAX: parameters are pytrees (nested dicts of jnp arrays),
modules are pairs of ``init_*`` / pure-apply functions.  Layer stacks are
stored with a leading ``layer`` axis and consumed with ``lax.scan`` so the
traced HLO is O(1) in depth (critical for the 512-device dry-run compiles).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config type for every assigned architecture family."""

    name: str = "model"
    family: str = "dense"  # dense | ssm | encdec | vlm | moe | hybrid
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    qkv_bias: bool = False  # qwen1.5
    tie_embeddings: bool = True
    scale_embed: bool = False  # gemma: embeddings * sqrt(d_model)
    # sliding window attention (None = full causal).  ``long_context_window``
    # is the window substituted when the long_500k shape is requested for an
    # arch whose base attention is full-causal (see DESIGN.md §5).
    sliding_window: Optional[int] = None
    long_context_window: int = 8192
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # deepseek-v2: layer 0 is a dense MLP
    router_aux_coef: float = 0.01
    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_n_groups: int = 1
    # --- hybrid (recurrentgemma) ---
    rg_pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    rg_lru_width: int = 0  # 0 -> d_model
    local_window: int = 2048
    # --- enc-dec (seamless) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- vlm (paligemma) ---
    n_prefix_tokens: int = 0  # SigLIP patch count; embeddings come pre-computed
    # --- training memory policy ---
    remat: bool = False  # per-layer activation checkpointing in lax.scan
    # --- numerics ---
    dtype: Any = jnp.float32

    @property
    def n_rep(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_lru(self) -> int:
        return self.rg_lru_width or self.d_model

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def dense_param(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    kw, kb = jax.random.split(key)
    p = {"w": normal_init(kw, (d_in, d_out), dtype, scale=d_in ** -0.5)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + weight.astype(jnp.float32))).astype(dt)


def init_rms(d: int, dtype) -> jnp.ndarray:
    # stored as (scale - 1) like gemma/llama "weight + 1" convention simplified:
    # we keep zeros and add 1 inside rms_norm.
    return jnp.zeros((d,), dtype)


def activate(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown act {act}")


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": dense_param(kg, d_model, d_ff, dtype),
        "up": dense_param(ku, d_model, d_ff, dtype),
        "down": dense_param(kd, d_ff, d_model, dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    return dense_apply(p["down"], activate(dense_apply(p["gate"], x), act) * dense_apply(p["up"], x))


# ---------------------------------------------------------------------------
# rotary position embeddings (llama-style half rotation)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,T,1,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": normal_init(key, (vocab, d_model), dtype)}


def embed_apply(p: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = jnp.take(p["table"], tokens, axis=0)
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def unembed_apply(p: Params, h: jnp.ndarray) -> jnp.ndarray:
    return h @ p["table"].T


# ---------------------------------------------------------------------------
# layer stacking helpers
# ---------------------------------------------------------------------------
def stack_layers(init_one: Callable[[jax.Array], Params], key, n: int) -> Params:
    """Initialize n layers and stack each leaf along a leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


# Megatron-SP-style activation sequence sharding (launch sets this before
# tracing a sharded train step; see EXPERIMENTS.md §Perf iteration 1).  When
# set, the residual stream carried between layers is constrained to this
# PartitionSpec — GSPMD then keeps pointwise ops sequence-sharded and only
# gathers where attention genuinely needs the full sequence.
_ACTIVATION_SPEC = None


def set_activation_sharding(spec) -> None:
    global _ACTIVATION_SPEC
    _ACTIVATION_SPEC = spec


def _constrain(h):
    if _ACTIVATION_SPEC is not None and hasattr(h, "ndim") and h.ndim == 3:
        return jax.lax.with_sharding_constraint(h, _ACTIVATION_SPEC)
    return h


def scan_layers(body: Callable, h: jnp.ndarray, stacked: Params, *extra_xs,
                remat: bool = False):
    """lax.scan of ``body(h, per_layer_params, *per_layer_extras)``.

    body returns (new_h, per_layer_output or None).  ``remat=True`` wraps the
    body in jax.checkpoint (per-layer activation rematerialization for the
    training path).
    """

    def step(carry, xs):
        out, ys = body(_constrain(carry), *xs)
        return _constrain(out), ys

    if remat:
        step = jax.checkpoint(step)
    xs = (stacked,) + tuple(extra_xs)
    return jax.lax.scan(step, h, xs)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross entropy. logits (B,T,V); labels (B,T) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
