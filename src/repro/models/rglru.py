"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU gated linear
recurrences interleaved 2:1 with local sliding-window MQA attention.

Layer pattern: groups of (rec, rec, attn) consumed by one lax.scan over
groups; ``n_layers % 3`` leftover layers form a small recurrent tail stack.
Every temporal block is followed by a GeGLU MLP (both pre-norm, residual).

The RG-LRU train path uses ``jax.lax.associative_scan`` over the linear
recurrence h_t = a_t h_{t-1} + b_t (identity transition at left pads);
decode is the exact one-step recurrence.  Decode state is O(1) in context
length (conv tail + h per rec layer, window-sized KV ring per attn layer),
so long_500k runs natively (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, Params, dense_apply, dense_param,
                                 embed_apply, init_embed, init_mlp, init_rms,
                                 mlp_apply, normal_init, rms_norm, scan_layers,
                                 stack_layers, unembed_apply)

_RG_C = 8.0  # Griffin's fixed recurrence sharpness constant


class RGCache(NamedTuple):
    conv: jnp.ndarray      # (G, 2, B, W-1, d_lru)
    h: jnp.ndarray         # (G, 2, B, d_lru)
    attn_k: jnp.ndarray    # (G, B, Wloc, 1, D)
    attn_v: jnp.ndarray
    tail_conv: jnp.ndarray  # (Tt, B, W-1, d_lru)
    tail_h: jnp.ndarray     # (Tt, B, d_lru)
    slot_pos: jnp.ndarray   # (B, Wloc)
    write_idx: jnp.ndarray
    lengths: jnp.ndarray


def n_groups_tail(cfg: ModelConfig) -> Tuple[int, int]:
    return cfg.n_layers // 3, cfg.n_layers % 3


# ---------------------------------------------------------------------------
# RG-LRU + recurrent block
# ---------------------------------------------------------------------------
def init_rec_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4, k5, km = jax.random.split(key, 6)
    d, dl = cfg.d_model, cfg.d_lru
    return {
        "w_in": dense_param(k1, d, dl, cfg.dtype),
        "w_gate": dense_param(k2, d, dl, cfg.dtype),
        "w_out": dense_param(k3, dl, d, cfg.dtype),
        "conv_w": normal_init(k4, (cfg.ssm_conv_width, dl), cfg.dtype, 0.2),
        "conv_b": jnp.zeros((dl,), cfg.dtype),
        "lru_a": dense_param(k5, dl, dl, cfg.dtype),  # recurrence gate W_a
        "lru_x": dense_param(km, dl, dl, cfg.dtype),  # input gate W_x
        "lambda": jnp.full((dl,), 1.0, jnp.float32),  # softplus -> a in (0,1)
        "ln": init_rms(d, cfg.dtype),
        "mlp": init_mlp(jax.random.fold_in(key, 7), d, cfg.d_ff, cfg.dtype),
        "ln_mlp": init_rms(d, cfg.dtype),
    }


def _rglru_coeffs(p: Params, u: jnp.ndarray, valid: jnp.ndarray):
    """u (B,T,dl) conv output -> (log_a, b) for h_t = e^{log_a} h + b."""
    r = jax.nn.sigmoid(dense_apply(p["lru_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["lru_x"], u).astype(jnp.float32))
    log_a = -_RG_C * jax.nn.softplus(p["lambda"]) * r  # (B,T,dl) <= 0
    log_a = jnp.where(valid[..., None], log_a, 0.0)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * u.astype(jnp.float32)
    b = jnp.where(valid[..., None], b, 0.0)
    return log_a, b


def _assoc_scan(log_a, b, h0=None):
    """Linear recurrence via associative scan. Returns all h_t (B,T,dl)."""
    if h0 is not None:
        # fold initial state in as a virtual step: h_1 = a_1 (h0) + b_1
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return hs


def _conv(p: Params, u: jnp.ndarray, tail: Optional[jnp.ndarray] = None):
    W = p["conv_w"].shape[0]
    if tail is None:
        x = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x = jnp.concatenate([tail, u], axis=1)
    out = sum(x[:, i:i + u.shape[1], :] * p["conv_w"][i][None, None] for i in range(W))
    return out + p["conv_b"]


def rec_block_forward(p: Params, h: jnp.ndarray, valid: jnp.ndarray,
                      cfg: ModelConfig, conv_tail=None, h0=None):
    """Returns (new_h, final_lru_state, new_conv_tail)."""
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x), approximate=True)
    u = dense_apply(p["w_in"], x)
    u = jnp.where(valid[..., None], u, 0.0)
    uc = _conv(p, u, conv_tail)
    log_a, b = _rglru_coeffs(p, uc, valid)
    hs = _assoc_scan(log_a, b, h0)
    y = dense_apply(p["w_out"], hs.astype(h.dtype) * gate)
    h = h + y
    h = h + mlp_apply(p["mlp"], rms_norm(h, p["ln_mlp"], cfg.norm_eps), cfg.act)
    W = cfg.ssm_conv_width
    return h, hs[:, -1], u[:, -(W - 1):]


def rec_block_decode(p: Params, h: jnp.ndarray, conv_state: jnp.ndarray,
                     lru_h: jnp.ndarray, cfg: ModelConfig):
    """h (B,1,d); conv_state (B,W-1,dl); lru_h (B,dl) fp32."""
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x), approximate=True)
    u = dense_apply(p["w_in"], x)[:, 0]  # (B,dl)
    window = jnp.concatenate([conv_state, u[:, None]], axis=1)
    uc = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    log_a, b = _rglru_coeffs(p, uc[:, None], jnp.ones((uc.shape[0], 1), bool))
    new_h = jnp.exp(log_a[:, 0]) * lru_h + b[:, 0]
    y = dense_apply(p["w_out"], new_h[:, None].astype(h.dtype) * gate)
    h = h + y
    h = h + mlp_apply(p["mlp"], rms_norm(h, p["ln_mlp"], cfg.norm_eps), cfg.act)
    return h, window[:, 1:], new_h


# ---------------------------------------------------------------------------
# attention block (local MQA) — reuses models.attention
# ---------------------------------------------------------------------------
def init_attn_block(key, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(key)
    return {
        "attn": attn.init_attention(ka, cfg),
        "ln": init_rms(cfg.d_model, cfg.dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype),
        "ln_mlp": init_rms(cfg.d_model, cfg.dtype),
    }


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------
def init_group(key, cfg: ModelConfig) -> Params:
    kr, ka = jax.random.split(key)
    return {
        "rec": stack_layers(lambda k: init_rec_block(k, cfg), kr, 2),
        "attn": init_attn_block(ka, cfg),
    }


def init(key, cfg: ModelConfig) -> Params:
    G, Tt = n_groups_tail(cfg)
    ke, kg, kt = jax.random.split(key, 3)
    params = {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "groups": stack_layers(lambda k: init_group(k, cfg), kg, G),
        "ln_f": init_rms(cfg.d_model, cfg.dtype),
    }
    if Tt:
        params["tail"] = stack_layers(lambda k: init_rec_block(k, cfg), kt, Tt)
    return params


def _take(stacked: Params, i: int) -> Params:
    return jax.tree.map(lambda x: x[i], stacked)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    B, T = tokens.shape
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    from repro.models.transformer import make_positions
    positions = make_positions(tokens, lengths)
    valid = positions >= 0
    mask = (None if T >= attn.CHUNK_THRESHOLD
            else attn.prefill_mask(positions, cfg.local_window))
    h = embed_apply(params["embed"], tokens, cfg)
    h = jnp.where(valid[..., None], h, 0.0)

    def group_body(carry, group):
        g = carry
        for j in range(2):
            rp = _take(group["rec"], j)
            g, _, _ = rec_block_forward(rp, g, valid, cfg)
        ab = group["attn"]
        a = attn.attention_forward(ab["attn"], rms_norm(g, ab["ln"], cfg.norm_eps),
                                   positions, cfg, cfg.local_window, mask)
        g = g + a
        g = g + mlp_apply(ab["mlp"], rms_norm(g, ab["ln_mlp"], cfg.norm_eps), cfg.act)
        return g, None

    h, _ = scan_layers(group_body, h, params["groups"], remat=cfg.remat)
    if "tail" in params:
        def tail_body(carry, layer):
            g, _, _ = rec_block_forward(layer, carry, valid, cfg)
            return g, None
        h, _ = scan_layers(tail_body, h, params["tail"])
    return unembed_apply(params["embed"], rms_norm(h, params["ln_f"], cfg.norm_eps))


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: jnp.ndarray, cache_window: int = 0
            ) -> Tuple[jnp.ndarray, RGCache]:
    """``cache_window`` is the total requested width (L_i + S from the
    engine); the recurrent state is O(1) regardless.  Attention layers cache
    ``min(cfg.local_window, cache_window)`` ring slots."""
    B, T = tokens.shape
    from repro.models.transformer import make_positions
    positions = make_positions(tokens, lengths)
    valid = positions >= 0
    mask = (None if T >= attn.CHUNK_THRESHOLD
            else attn.prefill_mask(positions, cfg.local_window))
    if cache_window <= 0:
        cache_window = T + 64  # decode headroom fallback
    Wloc = min(cfg.local_window, cache_window)
    h = embed_apply(params["embed"], tokens, cfg)
    h = jnp.where(valid[..., None], h, 0.0)

    def group_body(carry, group):
        g = carry
        rec_states, rec_convs = [], []
        for j in range(2):
            rp = _take(group["rec"], j)
            g, st, ct = rec_block_forward(rp, g, valid, cfg)
            rec_states.append(st)
            rec_convs.append(ct)
        ab = group["attn"]
        x = rms_norm(g, ab["ln"], cfg.norm_eps)
        a, kc, vc = attn.attention_prefill(ab["attn"], x, positions, cfg,
                                           cfg.local_window, Wloc, mask=mask)
        g = g + a
        g = g + mlp_apply(ab["mlp"], rms_norm(g, ab["ln_mlp"], cfg.norm_eps), cfg.act)
        return g, (jnp.stack(rec_states), jnp.stack(rec_convs), kc, vc)

    h, (hs, convs, k_all, v_all) = scan_layers(group_body, h, params["groups"])

    Tt = cfg.n_layers % 3
    if Tt:
        def tail_body(carry, layer):
            g, st, ct = rec_block_forward(layer, carry, valid, cfg)
            return g, (st, ct)
        h, (tail_h, tail_conv) = scan_layers(tail_body, h, params["tail"])
    else:
        dl = cfg.d_lru
        tail_h = jnp.zeros((0, B, dl), jnp.float32)
        tail_conv = jnp.zeros((0, B, cfg.ssm_conv_width - 1, dl), cfg.dtype)

    logits = unembed_apply(params["embed"],
                           rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps))[:, 0]
    cache = RGCache(
        conv=convs, h=hs, attn_k=k_all, attn_v=v_all,
        tail_conv=tail_conv, tail_h=tail_h,
        slot_pos=attn.prefill_slot_pos(positions, Wloc),
        write_idx=jnp.asarray(T if Wloc >= T else Wloc, jnp.int32),
        lengths=lengths.astype(jnp.int32),
    )
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: RGCache,
                tokens: jnp.ndarray, step: jnp.ndarray
                ) -> Tuple[jnp.ndarray, RGCache]:
    q_pos = cache.lengths + step
    # note: keep the group axis so KVCache.window reads shape[2] == Wloc
    fake = attn.KVCache(cache.attn_k, cache.attn_v, cache.slot_pos,
                        cache.write_idx, cache.lengths)
    slot = attn.decode_slot(fake)
    slot_pos = attn.decode_slot_pos(fake, q_pos)
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def group_body(carry, group, conv, lru_h, kc, vc):
        g = carry
        new_conv, new_h = [], []
        for j in range(2):
            rp = _take(group["rec"], j)
            g, cj, hj = rec_block_decode(rp, g, conv[j], lru_h[j], cfg)
            new_conv.append(cj)
            new_h.append(hj)
        ab = group["attn"]
        x = rms_norm(g, ab["ln"], cfg.norm_eps)
        a, kc, vc = attn.attention_decode(ab["attn"], x, q_pos, kc, vc,
                                          slot_pos, slot, cfg, cfg.local_window)
        g = g + a
        g = g + mlp_apply(ab["mlp"], rms_norm(g, ab["ln_mlp"], cfg.norm_eps), cfg.act)
        return g, (jnp.stack(new_conv), jnp.stack(new_h), kc, vc)

    h, (convs, hs, k_all, v_all) = scan_layers(
        group_body, h, params["groups"], cache.conv, cache.h,
        cache.attn_k, cache.attn_v)

    if cache.tail_h.shape[0]:
        def tail_body(carry, layer, conv, lru_h):
            g, cj, hj = rec_block_decode(layer, carry, conv, lru_h, cfg)
            return g, (cj, hj)
        h, (tail_conv, tail_h) = scan_layers(tail_body, h, params["tail"],
                                             cache.tail_conv, cache.tail_h)
    else:
        tail_conv, tail_h = cache.tail_conv, cache.tail_h

    logits = unembed_apply(params["embed"],
                           rms_norm(h, params["ln_f"], cfg.norm_eps))[:, 0]
    return logits, cache._replace(conv=convs, h=hs, attn_k=k_all, attn_v=v_all,
                                  tail_conv=tail_conv, tail_h=tail_h,
                                  slot_pos=slot_pos, write_idx=cache.write_idx + 1)
