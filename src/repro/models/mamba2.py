"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in raw JAX.

The temporal mixer is the SSD chunked algorithm: quadratic attention-like
computation *within* chunks of ``Q = cfg.ssm_chunk`` tokens plus a cheap
inter-chunk recurrence over (H, P, N) states — O(T·Q) instead of O(T²),
and the exact recurrence used token-by-token at decode time.

Left-padding convention: pad tokens contribute nothing (inputs and dt are
masked to zero, giving an identity state transition), so the SSM state after
prefill is exactly the state after the real tokens.

Decode cache = per-layer (conv ring state, SSD state) — constant memory,
which is why long_500k runs natively for this arch (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import ssd_scan_ref as _ssd_chunked
from repro.models.common import (ModelConfig, Params, dense_apply, dense_param,
                                 embed_apply, init_embed, init_rms, rms_norm,
                                 scan_layers, stack_layers, unembed_apply,
                                 normal_init)


class MambaCache(NamedTuple):
    conv: jnp.ndarray  # (L, B, W-1, conv_dim) — last W-1 conv inputs
    state: jnp.ndarray  # (L, B, H, P, N) SSD state
    lengths: jnp.ndarray  # (B,) int32


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_d_inner
    H, P, N, G = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    conv_dim = d_in + 2 * G * N
    return d_in, H, P, N, G, conv_dim


def init_mixer(key, cfg: ModelConfig) -> Params:
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    zxbcdt = 2 * d_in + 2 * G * N + H
    return {
        "in_proj": dense_param(k1, cfg.d_model, zxbcdt, cfg.dtype),
        "conv_w": normal_init(k2, (cfg.ssm_conv_width, conv_dim), cfg.dtype, 0.2),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1 at init
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rms(d_in, cfg.dtype),
        "out_proj": dense_param(k3, d_in, cfg.d_model, cfg.dtype),
    }


def _split_zxbcdt(p: Params, u: jnp.ndarray, cfg: ModelConfig):
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    zxbcdt = dense_apply(p["in_proj"], u)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    return z, xBC, dt


def _causal_conv(p: Params, xBC: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width W. xBC (B,T,C); pads already zeroed."""
    W = p["conv_w"].shape[0]
    x = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(x[:, i:i + xBC.shape[1], :] * p["conv_w"][i][None, None]
              for i in range(W))
    return jax.nn.silu(out + p["conv_b"])


# The chunked SSD scan lives in repro.kernels.ref:ssd_scan_ref — it is
# both this model's temporal mixer (XLA path) and the allclose oracle for
# the ssd_scan Pallas kernel, so there is exactly one copy of the math.


def mixer_forward(p: Params, u: jnp.ndarray, valid: jnp.ndarray, cfg: ModelConfig,
                  init_state: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """u (B,T,d_model); valid (B,T) bool. Returns (out, final_state, conv_tail)."""
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    B_, T, _ = u.shape
    z, xBC, dt = _split_zxbcdt(p, u, cfg)
    xBC = jnp.where(valid[..., None], xBC, 0.0)
    xBC_conv = _causal_conv(p, xBC, valid)
    x, Bmat, Cmat = jnp.split(xBC_conv, [d_in, d_in + G * N], axis=-1)
    x = x.reshape(B_, T, H, P)
    Bmat = Bmat.reshape(B_, T, G, N)
    Cmat = Cmat.reshape(B_, T, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.where(valid[..., None], dt, 0.0)  # identity transition at pads
    A = -jnp.exp(p["A_log"])
    y, final = _ssd_chunked(x.astype(jnp.float32), dt, A,
                            Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                            cfg.ssm_chunk, init_state)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    conv_tail = xBC[:, -(cfg.ssm_conv_width - 1):, :]  # last W-1 raw conv inputs
    return dense_apply(p["out_proj"], y), final, conv_tail


def mixer_decode(p: Params, u: jnp.ndarray, conv_state: jnp.ndarray,
                 ssm_state: jnp.ndarray, cfg: ModelConfig):
    """One-token recurrence. u (B,1,d); conv_state (B,W-1,conv_dim);
    ssm_state (B,H,P,N)."""
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    B_ = u.shape[0]
    z, xBC, dt = _split_zxbcdt(p, u, cfg)
    xBC = xBC[:, 0]  # (B, conv_dim)
    window = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # (B,W,conv)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    x, Bmat, Cmat = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    x = x.reshape(B_, H, P).astype(jnp.float32)
    Bmat = jnp.repeat(Bmat.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    Cmat = jnp.repeat(Cmat.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B,H)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bmat, x)
    new_state = ssm_state * a[:, :, None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cmat, new_state) + x * p["D"][None, :, None]
    y = y.reshape(B_, 1, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return dense_apply(p["out_proj"], y), window[:, 1:], new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig) -> Params:
    km = jax.random.split(key, 1)[0]
    return {"mixer": init_mixer(km, cfg), "ln": init_rms(cfg.d_model, cfg.dtype)}


def init(key, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(key)
    return {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": stack_layers(lambda k: init_block(k, cfg), kl, cfg.n_layers),
        "ln_f": init_rms(cfg.d_model, cfg.dtype),
    }


def _pad_to_chunk(h, valid, Q):
    T = h.shape[1]
    lead = (-T) % Q
    if lead:
        h = jnp.pad(h, ((0, 0), (lead, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (lead, 0)))
    return h, valid, lead


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    B, T = tokens.shape
    if valid is None:
        valid = jnp.ones((B, T), bool)
    h = embed_apply(params["embed"], tokens, cfg)
    h = jnp.where(valid[..., None], h, 0.0)
    h, valid_p, lead = _pad_to_chunk(h, valid, cfg.ssm_chunk)

    def body(carry, layer):
        o, _, _ = mixer_forward(layer["mixer"],
                                rms_norm(carry, layer["ln"], cfg.norm_eps),
                                valid_p, cfg)
        return carry + o, None

    h, _ = scan_layers(body, h, params["layers"], remat=cfg.remat)
    h = h[:, lead:]
    return unembed_apply(params["embed"], rms_norm(h, params["ln_f"], cfg.norm_eps))


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: jnp.ndarray, cache_window: int = 0,
            ) -> Tuple[jnp.ndarray, MambaCache]:
    """cache_window is ignored (constant-size state) — kept for API parity."""
    B, T = tokens.shape
    idx = jnp.arange(T)[None]
    valid = idx >= (T - lengths[:, None])
    h = embed_apply(params["embed"], tokens, cfg)
    h = jnp.where(valid[..., None], h, 0.0)
    h, valid_p, lead = _pad_to_chunk(h, valid, cfg.ssm_chunk)

    def body(carry, layer):
        o, st, conv_tail = mixer_forward(layer["mixer"],
                                         rms_norm(carry, layer["ln"], cfg.norm_eps),
                                         valid_p, cfg)
        return carry + o, (st, conv_tail)

    h, (states, conv_tails) = scan_layers(body, h, params["layers"])
    logits = unembed_apply(params["embed"],
                           rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps))[:, 0]
    cache = MambaCache(conv=conv_tails, state=states, lengths=lengths.astype(jnp.int32))
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: MambaCache,
                tokens: jnp.ndarray, step: jnp.ndarray
                ) -> Tuple[jnp.ndarray, MambaCache]:
    h = embed_apply(params["embed"], tokens[:, None], cfg)

    def body(carry, layer, conv, state):
        o, conv, state = mixer_decode(layer["mixer"],
                                      rms_norm(carry, layer["ln"], cfg.norm_eps),
                                      conv, state, cfg)
        return carry + o, (conv, state)

    h, (convs, states) = scan_layers(body, h, params["layers"], cache.conv, cache.state)
    logits = unembed_apply(params["embed"],
                           rms_norm(h, params["ln_f"], cfg.norm_eps))[:, 0]
    return logits, cache._replace(conv=convs, state=states)
