"""Unified model interface over all architecture families.

``get_model(cfg)`` returns a ``Model`` with five pure functions sharing one
calling convention, so the engine / trainer / dry-run never dispatch on the
family themselves:

  init(key)                                      -> params
  loss(params, batch)                            -> scalar
  prefill(params, batch, cache_window)           -> (last_logits, cache)
  decode_step(params, cache, tokens, step)       -> (logits, cache)
  kv_bytes_per_token(n_model_shards)             -> float  (Δ in Eq. 5)

``batch`` is a dict: tokens (B,T) int32, lengths (B,) int32, and optionally
labels / loss_mask (train), src_embeds or prefix_embeds (audio / vlm stubs).
``window_override`` lets the long_500k shape force a sliding window on
otherwise full-attention archs (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, mamba2, moe, rglru, transformer
from repro.models.common import ModelConfig, softmax_xent


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    kv_bytes_per_token: Callable


def _dtype_bytes(cfg: ModelConfig) -> int:
    return jnp.dtype(cfg.dtype).itemsize


def _loss_mask(batch: Dict[str, Any]) -> Optional[jnp.ndarray]:
    if "loss_mask" in batch:
        return batch["loss_mask"]
    return None


# ---------------------------------------------------------------------------
# per-family adapters
# ---------------------------------------------------------------------------
def _dense(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        window = batch.get("window_override", cfg.sliding_window)
        logits = transformer.forward(params, cfg, batch["tokens"],
                                     prefix_embeds=batch.get("prefix_embeds"),
                                     window=window)
        if cfg.n_prefix_tokens and "prefix_embeds" in batch:
            logits = logits[:, batch["prefix_embeds"].shape[1]:]
        return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], _loss_mask(batch))

    def prefill(params, batch, cache_window, window=None):
        return transformer.prefill(params, cfg, batch["tokens"], batch["lengths"],
                                   cache_window,
                                   prefix_embeds=batch.get("prefix_embeds"),
                                   window=window)

    def decode_step(params, cache, tokens, step, window=None):
        return transformer.decode_step(params, cfg, cache, tokens, step, window=window)

    def kv_bytes(n_shards=1):
        per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * _dtype_bytes(cfg)
        shard = min(n_shards, cfg.n_kv_heads)  # MQA replicates KV on model axis
        return per_tok / shard

    return Model(cfg, lambda k: transformer.init(k, cfg), loss, prefill,
                 decode_step, kv_bytes)


def _ssm(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        logits = mamba2.forward(params, cfg, batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], _loss_mask(batch))

    def prefill(params, batch, cache_window, window=None):
        return mamba2.prefill(params, cfg, batch["tokens"], batch["lengths"])

    def decode_step(params, cache, tokens, step, window=None):
        return mamba2.decode_step(params, cfg, cache, tokens, step)

    def kv_bytes(n_shards=1):
        # constant-size state, amortized over the slice: report the marginal
        # per-token cost as 0 and expose the fixed state separately.
        return 0.0

    return Model(cfg, lambda k: mamba2.init(k, cfg), loss, prefill,
                 decode_step, kv_bytes)


def _hybrid(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        logits = rglru.forward(params, cfg, batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], _loss_mask(batch))

    def prefill(params, batch, cache_window, window=None):
        return rglru.prefill(params, cfg, batch["tokens"], batch["lengths"], cache_window)

    def decode_step(params, cache, tokens, step, window=None):
        return rglru.decode_step(params, cfg, cache, tokens, step)

    def kv_bytes(n_shards=1):
        n_attn = cfg.n_layers // 3
        per_tok = 2 * n_attn * cfg.n_kv_heads * cfg.head_dim * _dtype_bytes(cfg)
        return per_tok / min(n_shards, cfg.n_kv_heads)

    return Model(cfg, lambda k: rglru.init(k, cfg), loss, prefill,
                 decode_step, kv_bytes)


def _moe(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        window = batch.get("window_override", cfg.sliding_window)
        logits, aux = moe.forward(params, cfg, batch["tokens"],
                                  batch.get("lengths"), window=window)
        xent = softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], _loss_mask(batch))
        return xent + cfg.router_aux_coef * aux

    def prefill(params, batch, cache_window, window=None):
        return moe.prefill(params, cfg, batch["tokens"], batch["lengths"],
                           cache_window, window=window)

    def decode_step(params, cache, tokens, step, window=None):
        return moe.decode_step(params, cfg, cache, tokens, step, window=window)

    def kv_bytes(n_shards=1):
        b = _dtype_bytes(cfg)
        if cfg.use_mla:  # latent + shared rope key, replicated across heads
            return cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * b
        per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * b
        return per_tok / min(n_shards, cfg.n_kv_heads)

    return Model(cfg, lambda k: moe.init(k, cfg), loss, prefill,
                 decode_step, kv_bytes)


def _encdec(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        logits = encdec.forward(params, cfg, batch["src_embeds"], batch["tokens"],
                                batch.get("src_valid"))
        return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], _loss_mask(batch))

    def prefill(params, batch, cache_window, window=None):
        return encdec.prefill(params, cfg, batch["src_embeds"], batch["tokens"],
                              batch["lengths"], cache_window,
                              batch.get("src_valid"), window=window)

    def decode_step(params, cache, tokens, step, window=None):
        return encdec.decode_step(params, cfg, cache, tokens, step, window=window)

    def kv_bytes(n_shards=1):
        # decoder self-attention cache only (cross-KV is per-schedule constant)
        per_tok = 2 * cfg.n_dec_layers * cfg.n_kv_heads * cfg.head_dim * _dtype_bytes(cfg)
        return per_tok / min(n_shards, cfg.n_kv_heads)

    return Model(cfg, lambda k: encdec.init(k, cfg), loss, prefill,
                 decode_step, kv_bytes)


_FAMILIES = {
    "dense": _dense,
    "vlm": _dense,  # prefix-LM rides the dense path (prefix_embeds in batch)
    "ssm": _ssm,
    "hybrid": _hybrid,
    "moe": _moe,
    "encdec": _encdec,
}


def get_model(cfg: ModelConfig) -> Model:
    return _FAMILIES[cfg.family](cfg)
