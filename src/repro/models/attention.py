"""Attention layers with KV caches for static-batch serving.

Cache design (see DESIGN.md §6):
  * static batching left-pads the batch to ``L_i`` (bucketed), so all requests
    share cache slot indices: slot ``j`` is written by global step ``j`` for
    every batch row.  Real positions differ per row (left padding), so we keep
    ``slot_pos`` (B, W) with the absolute position stored in each slot
    (-1 = empty / pad).
  * the cache has exactly ``W = L_i + S`` slots for slice-level serving — the
    paper's memory model Eq. (5) — or ``W = window`` as a ring buffer for
    sliding-window attention (long-context decode).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Params, apply_rope, dense_param,
                                 dense_apply)

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


class KVCache(NamedTuple):
    """Per-model KV cache; k/v carry a leading layer axis."""

    k: jnp.ndarray  # (L, B, W, Hkv, D)
    v: jnp.ndarray  # (L, B, W, Hkv, D)
    slot_pos: jnp.ndarray  # (B, W) int32 absolute position per slot, -1 empty
    write_idx: jnp.ndarray  # () int32 — next global slot counter
    lengths: jnp.ndarray  # (B,) int32 — real (unpadded) input lengths

    @property
    def window(self) -> int:
        return self.k.shape[2]


def init_kv_cache(n_layers: int, batch: int, window: int, n_kv: int, head_dim: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((n_layers, batch, window, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, window, n_kv, head_dim), dtype),
        slot_pos=jnp.full((batch, window), -1, jnp.int32),
        write_idx=jnp.zeros((), jnp.int32),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# core attention math (jnp reference; Pallas kernels mirror this in kernels/)
# ---------------------------------------------------------------------------
def gqa_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               mask: jnp.ndarray, scale: float) -> jnp.ndarray:
    """q (B,T,Hq,D), k/v (B,S,Hkv,D), mask (B,1,T,S) bool -> (B,T,Hq,D)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, T, Hkv, G, D)
    # f32 accumulation WITHOUT materializing f32 copies of K/V (the cache
    # can be tens of GB; astype would double-buffer it — §Perf iteration C2)
    scores = jnp.einsum("bthgd,bshd->bhgts", qr, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)  # (B,1,1,T,S) bcast
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, T, Hq, v.shape[-1]).astype(q.dtype)  # Dv may differ (MLA)


def prefill_mask(positions: jnp.ndarray, window: Optional[int]) -> jnp.ndarray:
    """Causal mask over left-padded prefill. positions (B,T) with pads < 0."""
    pq = positions[:, :, None]  # (B,T,1)
    pk = positions[:, None, :]  # (B,1,S)
    m = (pk >= 0) & (pk <= pq)
    if window is not None:
        m = m & (pq - pk < window)
    # pad query rows would be fully masked -> allow the diagonal to avoid NaN
    T = positions.shape[1]
    m = m | jnp.eye(T, dtype=bool)[None]
    return m[:, None]  # (B,1,T,S)


def decode_mask(q_pos: jnp.ndarray, slot_pos: jnp.ndarray,
                window: Optional[int]) -> jnp.ndarray:
    """q_pos (B,), slot_pos (B,W) -> (B,1,1,W)."""
    m = (slot_pos >= 0) & (slot_pos <= q_pos[:, None])
    if window is not None:
        m = m & (q_pos[:, None] - slot_pos < window)
    return m[:, None, None]


# ---------------------------------------------------------------------------
# chunked attention (XLA fallback for long sequences)
#
# Materializing (B,·,T,S) scores at T=4k–32k would blow HBM; the q axis is
# scanned in blocks of `block_q`, with masks rebuilt per block from positions
# (never materialized at (T,S)).  The Pallas flash kernel replaces this on
# real TPU runs; this path is what the dry-run lowers (DESIGN.md §4).
# ---------------------------------------------------------------------------
CHUNK_THRESHOLD = 2048  # use the chunked path at or above this many tokens
_DEFAULT_BLOCK_Q = 512


def _chunk_mask(pq: jnp.ndarray, pk: jnp.ndarray, window: Optional[int],
                prefix_len: int, valid_q=None, valid_k=None) -> jnp.ndarray:
    """pq (B,bq), pk (B,S) -> (B,bq,S) bool."""
    pqe, pke = pq[:, :, None], pk[:, None, :]
    if valid_k is not None:  # bidirectional (encoder / cross-attention)
        m = jnp.broadcast_to(valid_k[:, None, :], pqe.shape[:2] + (pk.shape[1],))
        if valid_q is not None:
            m = m | (~valid_q[:, :, None] & ~valid_k[:, None, :])
        return m
    m = (pke >= 0) & (pke <= pqe)
    if window is not None:
        m = m & (pqe - pke < window)
    if prefix_len:
        m = m | ((pke >= 0) & (pke < prefix_len) & (pqe >= 0))
    return m | ((pqe < 0) & (pke < 0))  # pads attend pads (NaN guard)


def gqa_attend_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       scale: float, pos_q: jnp.ndarray, pos_k: jnp.ndarray,
                       window: Optional[int], prefix_len: int = 0,
                       valid_q=None, valid_k=None,
                       block_q: int = _DEFAULT_BLOCK_Q) -> jnp.ndarray:
    """Scan over q blocks; full K/V per block. Shapes as gqa_attend."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, T)
    while T % bq:
        bq //= 2
    nq = T // bq
    qr = q.reshape(B, nq, bq, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    pqr = pos_q.reshape(B, nq, bq).transpose(1, 0, 2)
    vqr = (valid_q.reshape(B, nq, bq).transpose(1, 0, 2)
           if valid_q is not None else None)

    def chunk(_, xs):
        if vqr is None:
            qc, pqc = xs
            vq = None
        else:
            qc, pqc, vq = xs
        s = jnp.einsum("bqhgd,bshd->bhgqs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        m = _chunk_mask(pqc, pos_k, window, prefix_len, vq, valid_k)
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return None, o

    xs = (qr, pqr) if vqr is None else (qr, pqr, vqr)
    _, o = jax.lax.scan(chunk, None, xs)
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, Hq, v.shape[-1])  # Dv != Dq (MLA)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention layer
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": dense_param(kq, cfg.d_model, Hq * D, cfg.dtype, bias=cfg.qkv_bias),
        "wk": dense_param(kk, cfg.d_model, Hkv * D, cfg.dtype, bias=cfg.qkv_bias),
        "wv": dense_param(kv, cfg.d_model, Hkv * D, cfg.dtype, bias=cfg.qkv_bias),
        "wo": dense_param(ko, Hq * D, cfg.d_model, cfg.dtype),
    }


def _qkv(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    B, T, _ = x.shape
    q = dense_apply(p["wq"], x).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = dense_apply(p["wk"], x).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(p["wv"], x).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def attention_forward(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                      cfg: ModelConfig, window: Optional[int],
                      mask: Optional[jnp.ndarray] = None,
                      prefix_len: int = 0,
                      valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence (train / prefill without cache return) attention.

    Long sequences (T >= CHUNK_THRESHOLD) take the q-blocked path and build
    masks per block from ``positions`` / ``prefix_len`` / ``valid`` —
    callers should pass ``mask=None`` there."""
    q, k, v = _qkv(p, x, cfg)
    rp = jnp.maximum(positions, 0)
    q = apply_rope(q, rp, cfg.rope_theta)
    k = apply_rope(k, rp, cfg.rope_theta)
    scale = cfg.head_dim ** -0.5
    if x.shape[1] >= CHUNK_THRESHOLD:
        o = gqa_attend_chunked(q, k, v, scale, positions, positions, window,
                               prefix_len, valid_q=valid, valid_k=valid)
    else:
        if mask is None:
            mask = prefill_mask(positions, window)
        o = gqa_attend(q, k, v, mask, scale)
    return dense_apply(p["wo"], o.reshape(x.shape[0], x.shape[1], -1))


def attention_prefill(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                      cfg: ModelConfig, window: Optional[int], cache_window: int,
                      mask: Optional[jnp.ndarray] = None, prefix_len: int = 0,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill that also returns per-layer (k_cache, v_cache) of width W."""
    B, T, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    rp = jnp.maximum(positions, 0)
    q = apply_rope(q, rp, cfg.rope_theta)
    k = apply_rope(k, rp, cfg.rope_theta)
    scale = cfg.head_dim ** -0.5
    if T >= CHUNK_THRESHOLD:
        o = gqa_attend_chunked(q, k, v, scale, positions, positions, window,
                               prefix_len)
    else:
        if mask is None:
            mask = prefill_mask(positions, window)
        o = gqa_attend(q, k, v, mask, scale)
    out = dense_apply(p["wo"], o.reshape(B, T, -1))
    W = cache_window
    if W >= T:
        pad = [(0, 0), (0, W - T), (0, 0), (0, 0)]
        kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
    else:  # ring: keep the last W entries (window-limited decode)
        kc, vc = k[:, T - W:], v[:, T - W:]
    return out, kc, vc


def attention_prefill_paged(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                            cfg: ModelConfig, window: Optional[int],
                            k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                            block_table: jnp.ndarray,
                            mask: Optional[jnp.ndarray] = None,
                            impl: str = "unfused",
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill that lands K/V in the paged pool (``repro.kvcache``).

    The attention math is ``attention_prefill``'s exactly; instead of a
    padded dense (B, W) cache, each valid token's K/V is written to page
    ``block_table[b, pos // pg]`` at offset ``pos % pg`` via
    ``kernels.ops.paged_prefill_write`` (pads land in the null page).
    Mirrors ``attention_decode_paged`` so prefill and decode both read
    and write the same persistent page pool.  Returns
    (out, k_pages, v_pages).

    ``impl="fused"`` routes K through
    ``kernels.ops.fused_rope_prefill_write`` — RoPE applied in-register
    while the pages are written, no rotated-K tensor in HBM — and the
    queries attend against the rotated K/V gathered back from the pages
    (the read attention pays anyway).  ``"unfused"`` is the correctness
    baseline.  Long prompts (T >= CHUNK_THRESHOLD) always take the
    unfused chunked path.
    """
    from repro.kernels import ops as kernel_ops  # deferred: keep models importable without kernels
    assert impl in ("unfused", "fused"), impl
    B, T, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    rp = jnp.maximum(positions, 0)
    q = apply_rope(q, rp, cfg.rope_theta)
    scale = cfg.head_dim ** -0.5
    if impl == "fused" and T < CHUNK_THRESHOLD:
        # one pass over K: rotate in-register + write pages; attention
        # reads the rotated K/V back through the block tables (slot ==
        # position in the compact layout)
        k_pages, v_pages = kernel_ops.fused_rope_prefill_write(
            k, v, positions, block_table, k_pages, v_pages,
            theta=cfg.rope_theta)
        pg, Hkv = k_pages.shape[1], k_pages.shape[2]
        nb = block_table.shape[1]
        kw = k_pages[block_table].reshape(B, nb * pg, Hkv, k_pages.shape[-1])
        vw = v_pages[block_table].reshape(B, nb * pg, Hkv, v_pages.shape[-1])
        lengths = jnp.sum(positions >= 0, axis=1)
        slots = jnp.arange(nb * pg, dtype=jnp.int32)[None]
        pk = jnp.where(slots < lengths[:, None], slots, -1)[:, None, :]
        pq = positions[:, :, None]
        m = (pk >= 0) & (pk <= pq)
        if window is not None:
            m = m & (pq - pk < window)
        # pad query rows would be fully masked -> attend slot 0 (NaN guard)
        m = m | ((pq < 0) & (jnp.arange(nb * pg)[None, None, :] == 0))
        o = gqa_attend(q, kw, vw, m[:, None], scale)
        out = dense_apply(p["wo"], o.reshape(B, T, -1))
        return out, k_pages, v_pages
    k = apply_rope(k, rp, cfg.rope_theta)
    if T >= CHUNK_THRESHOLD:
        o = gqa_attend_chunked(q, k, v, scale, positions, positions, window)
    else:
        if mask is None:
            mask = prefill_mask(positions, window)
        o = gqa_attend(q, k, v, mask, scale)
    out = dense_apply(p["wo"], o.reshape(B, T, -1))
    k_pages, v_pages = kernel_ops.paged_prefill_write(
        k, v, positions, block_table, k_pages, v_pages)
    return out, k_pages, v_pages


def attention_prefill_tail_paged(p: Params, x: jnp.ndarray,
                                 positions: jnp.ndarray, cfg: ModelConfig,
                                 window: Optional[int],
                                 k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                                 block_table: jnp.ndarray,
                                 slot_pos: jnp.ndarray,
                                 impl: str = "unfused",
                                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Tail prefill over a paged pool whose head KV is already resident.

    The cross-request prefix-sharing path: ``x`` (B,T,d) holds only each
    row's *novel tail* tokens (left-padded; pad positions < 0) while the
    shared/retained prefix KV is reachable through ``block_table``.
    ``positions`` are absolute (prefix_len .. total_len-1) and double as
    the compact-layout destination slots; ``slot_pos`` (B, nb·pg) covers
    the full logical window *including* the tail slots.  Tail K/V is
    scattered into the pages first, then each tail query attends to the
    gathered full window under the ``slot_pos <= q_pos`` causal mask —
    intra-tail causality falls out of the same comparison, so one pass
    covers prefix attention and tail self-attention.  Shared prefix pages
    are only read: tail writes land at positions past the shared head by
    construction (the engine shares full pages only).

    ``impl="fused"`` fuses the tail K rotation into the page write
    (``kernels.ops.fused_rope_prefill_write``); the gathered-window
    attention below is shared by both impls.
    """
    from repro.kernels import ops as kernel_ops  # deferred: keep models importable without kernels
    assert impl in ("unfused", "fused"), impl
    B, T, _ = x.shape
    pg = k_pages.shape[1]
    nb = block_table.shape[1]
    q, k, v = _qkv(p, x, cfg)
    rp = jnp.maximum(positions, 0)
    q = apply_rope(q, rp, cfg.rope_theta)
    if impl == "fused":
        k_pages, v_pages = kernel_ops.fused_rope_prefill_write(
            k, v, positions, block_table, k_pages, v_pages,
            theta=cfg.rope_theta)
    else:
        k = apply_rope(k, rp, cfg.rope_theta)
        k_pages, v_pages = kernel_ops.paged_prefill_write(
            k, v, positions, block_table, k_pages, v_pages)
    Hkv = k_pages.shape[2]
    kw = k_pages[block_table].reshape(B, nb * pg, Hkv, k_pages.shape[-1])
    vw = v_pages[block_table].reshape(B, nb * pg, Hkv, v_pages.shape[-1])
    pq = positions[:, :, None]  # (B,T,1)
    pk = slot_pos[:, None, :]   # (B,1,S)
    m = (pk >= 0) & (pk <= pq)
    if window is not None:
        m = m & (pq - pk < window)
    # pad query rows would be fully masked -> attend slot 0 to avoid NaN
    # (their output is discarded; slot 0 always holds position 0 here)
    m = m | ((pq < 0) & (jnp.arange(nb * pg)[None, None, :] == 0))
    o = gqa_attend(q, kw, vw, m[:, None], cfg.head_dim ** -0.5)
    out = dense_apply(p["wo"], o.reshape(B, T, -1))
    return out, k_pages, v_pages


def attention_decode(p: Params, x: jnp.ndarray, q_pos: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     slot_pos: jnp.ndarray, slot: jnp.ndarray,
                     cfg: ModelConfig, window: Optional[int]
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x (B,1,d); k/v_cache (B,W,Hkv,D); slot () int32.

    Returns (out, new_k_cache, new_v_cache).  ``slot_pos`` must already
    include the *current* token position at ``slot`` (the model driver
    updates it once, shared across layers).
    """
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, q_pos[:, None], cfg.rope_theta)
    k = apply_rope(k, q_pos[:, None], cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    mask = decode_mask(q_pos, slot_pos, window)
    o = gqa_attend(q, k_cache, v_cache, mask, cfg.head_dim ** -0.5)
    out = dense_apply(p["wo"], o.reshape(B, 1, -1))
    return out, k_cache, v_cache


def attention_decode_rowslots(p: Params, x: jnp.ndarray, q_pos: jnp.ndarray,
                              k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                              slot_pos: jnp.ndarray, slots: jnp.ndarray,
                              cfg: ModelConfig, window: Optional[int]
                              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode with *per-row* write slots (continuous batching: each slot of
    the engine is at a different position).  slots (B,) int32."""
    B = x.shape[0]
    W = k_cache.shape[1]
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, q_pos[:, None], cfg.rope_theta)
    k = apply_rope(k, q_pos[:, None], cfg.rope_theta)
    oh = jax.nn.one_hot(slots, W, dtype=k_cache.dtype)[:, :, None, None]  # (B,W,1,1)
    k_cache = k_cache * (1 - oh) + k * oh
    v_cache = v_cache * (1 - oh) + v * oh
    mask = decode_mask(q_pos, slot_pos, window)
    o = gqa_attend(q, k_cache, v_cache, mask, cfg.head_dim ** -0.5)
    out = dense_apply(p["wo"], o.reshape(B, 1, -1))
    return out, k_cache, v_cache


def attention_decode_paged(p: Params, x: jnp.ndarray, q_pos: jnp.ndarray,
                           k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                           block_table: jnp.ndarray, slot_pos: jnp.ndarray,
                           slots: jnp.ndarray, cfg: ModelConfig,
                           window: Optional[int], impl: str = "unfused",
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode over a paged KV cache (``repro.kvcache``) with per-row slots.

    x (B,1,d); k/v_pages (P,pg,Hkv,D) shared page pool; block_table (B,nb)
    physical page per logical block; slot_pos (B,nb·pg) over *logical*
    slots (must already include the current token position at ``slots``,
    like the dense drivers); slots (B,) logical write slots.  The write
    scatters one token into page ``block_table[b, slots[b]//pg]``; rows
    whose blocks all point at the null page (inactive engine rows) write
    there harmlessly.  Attention goes through
    ``kernels.ops.paged_decode_attention`` — pure-jnp gather on CPU, the
    Pallas page-streaming kernel on TPU — so the engine's paged path runs
    the kernel end to end.

    ``impl="fused"`` hands the *unrotated* q/k/v to
    ``kernels.ops.fused_rope_decode_append`` — one launch rotates the new
    token, appends its K/V to the page slot, and streams the running
    softmax; ``"unfused"`` (jnp rope + XLA scatter + attention kernel) is
    the correctness baseline.
    """
    from repro.kernels import ops as kernel_ops  # deferred: keep models importable without kernels
    assert impl in ("unfused", "fused"), impl
    B = x.shape[0]
    pg = k_pages.shape[1]
    q, k, v = _qkv(p, x, cfg)
    if impl == "fused":
        o, k_pages, v_pages = kernel_ops.fused_rope_decode_append(
            q[:, 0], k[:, 0], v[:, 0], block_table, slot_pos, slots, q_pos,
            k_pages, v_pages, theta=cfg.rope_theta, window=window)
        out = dense_apply(p["wo"], o.reshape(B, 1, -1))
        return out, k_pages, v_pages
    q = apply_rope(q, q_pos[:, None], cfg.rope_theta)
    k = apply_rope(k, q_pos[:, None], cfg.rope_theta)
    pages = jnp.take_along_axis(block_table, (slots // pg)[:, None], axis=1)[:, 0]
    offs = slots % pg
    k_pages = k_pages.at[pages, offs].set(k[:, 0])
    v_pages = v_pages.at[pages, offs].set(v[:, 0])
    o = kernel_ops.paged_decode_attention(q[:, 0], k_pages, v_pages,
                                          block_table, slot_pos, q_pos,
                                          window=window)
    out = dense_apply(p["wo"], o.reshape(B, 1, -1))
    return out, k_pages, v_pages


# ---------------------------------------------------------------------------
# cache bookkeeping shared by all attention archs
# ---------------------------------------------------------------------------
def prefill_slot_pos(positions: jnp.ndarray, cache_window: int) -> jnp.ndarray:
    """slot_pos after prefill of T (possibly > W, ring) left-padded tokens."""
    B, T = positions.shape
    W = cache_window
    if W >= T:
        pad = jnp.full((B, W - T), -1, jnp.int32)
        return jnp.concatenate([positions.astype(jnp.int32), pad], axis=1)
    return positions[:, T - W:].astype(jnp.int32)


def decode_slot(cache: KVCache) -> jnp.ndarray:
    """Ring slot for the next decode write."""
    return jnp.remainder(cache.write_idx, cache.window)


def decode_slot_pos(cache: KVCache, q_pos: jnp.ndarray) -> jnp.ndarray:
    slot = decode_slot(cache)
    return jax.lax.dynamic_update_slice_in_dim(
        cache.slot_pos, q_pos[:, None].astype(jnp.int32), slot, axis=1)
