"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a per-token latent ``c_kv`` (kv_lora_rank) plus one
shared rope key (qk_rope_head_dim) — the cache stores only those, giving a
~20x smaller Δ (bytes/token) than naive GQA for the assigned config.  At
attention time k_nope/v are re-expanded from the latent via the up
projections (the "non-absorbed" formulation; weight absorption is evaluated
as a §Perf iteration).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, Params, apply_rope, dense_apply,
                                 dense_param, init_rms, rms_norm)


class MLACache(NamedTuple):
    ckv: jnp.ndarray  # (L, B, W, r)
    kr: jnp.ndarray   # (L, B, W, dr)
    slot_pos: jnp.ndarray
    write_idx: jnp.ndarray
    lengths: jnp.ndarray

    @property
    def window(self) -> int:
        return self.ckv.shape[2]


def decode_slot(cache: MLACache) -> jnp.ndarray:
    return jnp.remainder(cache.write_idx, cache.window)


def decode_slot_pos(cache: MLACache, q_pos: jnp.ndarray) -> jnp.ndarray:
    slot = decode_slot(cache)
    return jax.lax.dynamic_update_slice_in_dim(
        cache.slot_pos, q_pos[:, None].astype(jnp.int32), slot, axis=1)


def init_mla(key, cfg: ModelConfig) -> Params:
    kq, kd, ku, kv, ko = jax.random.split(key, 5)
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim, cfg.kv_lora_rank)
    return {
        "wq": dense_param(kq, cfg.d_model, H * (dn + dr), cfg.dtype),
        "w_dkv": dense_param(kd, cfg.d_model, r + dr, cfg.dtype),
        "ckv_norm": init_rms(r, cfg.dtype),
        "k_up": dense_param(ku, r, H * dn, cfg.dtype),
        "v_up": dense_param(kv, r, H * dv, cfg.dtype),
        "wo": dense_param(ko, H * dv, cfg.d_model, cfg.dtype),
    }


def _q_proj(p: Params, x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig):
    B, T, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = dense_apply(p["wq"], x).reshape(B, T, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, jnp.maximum(positions, 0), cfg.rope_theta)
    return jnp.concatenate([qn, qr], axis=-1)  # (B,T,H,dn+dr)


def _compress(p: Params, x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig):
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dkv = dense_apply(p["w_dkv"], x)
    ckv = rms_norm(dkv[..., :r], p["ckv_norm"], cfg.norm_eps)
    kr = dkv[..., r:][:, :, None, :]  # (B,T,1,dr) one shared rope head
    kr = apply_rope(kr, jnp.maximum(positions, 0), cfg.rope_theta)[:, :, 0]
    return ckv, kr  # (B,T,r), (B,T,dr)


def _expand_attend(p: Params, q: jnp.ndarray, ckv: jnp.ndarray, kr: jnp.ndarray,
                   mask, cfg: ModelConfig, positions=None,
                   window=None) -> jnp.ndarray:
    """q (B,T,H,dn+dr); ckv (B,S,r); kr (B,S,dr); mask (B,1,T,S) or None
    (None -> q-chunked path with per-block masks from ``positions``)."""
    B, T, H, _ = q.shape
    S = ckv.shape[1]
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    kn = dense_apply(p["k_up"], ckv).reshape(B, S, H, dn)
    v = dense_apply(p["v_up"], ckv).reshape(B, S, H, dv)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, kr.shape[-1]))],
                        axis=-1)
    scale = (dn + cfg.qk_rope_head_dim) ** -0.5
    if mask is None:
        o = attn.gqa_attend_chunked(q, k, v, scale, positions, positions, window)
    else:
        o = attn.gqa_attend(q, k, v, mask, scale)  # H == Hkv here
    return dense_apply(p["wo"], o.reshape(B, T, H * dv))


def mla_forward(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, window: Optional[int],
                mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    if mask is None and x.shape[1] < attn.CHUNK_THRESHOLD:
        mask = attn.prefill_mask(positions, window)
    q = _q_proj(p, x, positions, cfg)
    ckv, kr = _compress(p, x, positions, cfg)
    return _expand_attend(p, q, ckv, kr, mask, cfg, positions, window)


def mla_prefill(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, window: Optional[int], cache_window: int,
                mask: Optional[jnp.ndarray] = None):
    B, T, _ = x.shape
    if mask is None and T < attn.CHUNK_THRESHOLD:
        mask = attn.prefill_mask(positions, window)
    q = _q_proj(p, x, positions, cfg)
    ckv, kr = _compress(p, x, positions, cfg)
    out = _expand_attend(p, q, ckv, kr, mask, cfg, positions, window)
    W = cache_window
    if W >= T:
        ckv_c = jnp.pad(ckv, ((0, 0), (0, W - T), (0, 0)))
        kr_c = jnp.pad(kr, ((0, 0), (0, W - T), (0, 0)))
    else:
        ckv_c, kr_c = ckv[:, T - W:], kr[:, T - W:]
    return out, ckv_c, kr_c


def mla_decode(p: Params, x: jnp.ndarray, q_pos: jnp.ndarray,
               ckv_cache: jnp.ndarray, kr_cache: jnp.ndarray,
               slot_pos: jnp.ndarray, slot: jnp.ndarray,
               cfg: ModelConfig, window: Optional[int]):
    """x (B,1,d); ckv_cache (B,W,r); kr_cache (B,W,dr)."""
    q = _q_proj(p, x, q_pos[:, None], cfg)
    ckv, kr = _compress(p, x, q_pos[:, None], cfg)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, ckv, slot, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr, slot, axis=1)
    mask = attn.decode_mask(q_pos, slot_pos, window)
    out = _expand_attend(p, q, ckv_cache, kr_cache, mask, cfg)
    return out, ckv_cache, kr_cache
