"""Checkpointing: flatten pytrees to npz + a JSON manifest (no orbax)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):  # jax flattens dicts in sorted-key order
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    extra: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    arrays = _flatten({"params": params})
    if opt_state is not None:
        arrays.update(_flatten({"opt": opt_state}))
    np.savez(os.path.join(path, "arrays.npz"),
             **{k.replace("/", "|"): v for k, v in arrays.items()})
    manifest = {"step": step, "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, params_template, opt_template=None
                    ) -> Tuple[Any, Any, int, dict]:
    data = np.load(os.path.join(path, "arrays.npz"))
    arrays = {k.replace("|", "/"): data[k] for k in data.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def rebuild(template, prefix):
        leaves, treedef = jax.tree.flatten(template)
        paths = _flatten(template)
        # reconstruct in the same flatten order
        flat = _flatten(template, prefix)
        vals = [arrays[k] for k in flat]
        return jax.tree.unflatten(treedef, vals)

    params = rebuild(params_template, "params/")
    opt = rebuild(opt_template, "opt/") if opt_template is not None else None
    return params, opt, manifest["step"], manifest["extra"]
