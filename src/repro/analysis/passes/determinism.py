"""``determinism`` — golden-pinned modules must stay bit-reproducible.

The scheduler equivalence story (PR 3) pins ``SchedulerCore`` to golden
dispatch logs recorded pre-refactor, and the PR 6 tracer promises
byte-identical traces for identical seeds.  Both break silently the
moment a golden-pinned module consults a wall clock, an unseeded RNG,
object identity, or unordered-set iteration order.  This pass bans those
constructs in the configured modules (``core/`` and the ``SchedulerCore``
path by default):

  * wall clocks / entropy: ``time.time``, ``time.monotonic``,
    ``time.perf_counter`` (+ ``_ns`` variants), ``datetime.now/utcnow/
    today``, ``os.urandom``, ``uuid.uuid1/uuid4``;
  * unseeded randomness: any ``random.*`` module call, global-state
    ``np.random.*`` calls — seeded generator *construction*
    (``np.random.RandomState(seed)`` / ``default_rng(seed)``) is allowed,
    and instance methods on such generators never match;
  * identity ordering: the ``id()`` builtin (CPython address order) and
    the ``hash()`` builtin (string hashing is salted per process via
    ``PYTHONHASHSEED``);
  * unordered iteration: ``for``/comprehension iteration (or ``list``/
    ``tuple``/``iter``/``enumerate``/``.pop()``) over values statically
    known to be ``set``/``frozenset`` — wrap in ``sorted(...)`` instead.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.framework import AnalysisPass, Finding, SourceFile, register

_CLOCK_CALLS = {
    ("time", "time"), ("time", "time_ns"),
    ("time", "monotonic"), ("time", "monotonic_ns"),
    ("time", "perf_counter"), ("time", "perf_counter_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("os", "urandom"),
    ("uuid", "uuid1"), ("uuid", "uuid4"),
}
_SEEDED_CTORS = {"RandomState", "default_rng", "Generator", "PCG64",
                 "SeedSequence"}
_ITER_WRAPPERS = {"list", "tuple", "iter", "enumerate"}


def _dotted(expr: ast.expr) -> Optional[str]:
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if not isinstance(expr, ast.Name):
        return None
    parts.append(expr.id)
    return ".".join(reversed(parts))


def _is_set_expr(expr: ast.expr) -> bool:
    """Literally a set right here: ``{a, b}``, ``set(...)``,
    ``frozenset(...)``, a set comprehension, or ``a | b`` of sets? (the
    binop case is not tracked — assignments cover the repo's idiom)."""
    if isinstance(expr, (ast.Set, ast.SetComp)):
        return True
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
            and expr.func.id in ("set", "frozenset"):
        return True
    return False


def _is_set_annotation(ann: ast.expr) -> bool:
    """``Set[int]`` / ``set[int]`` / ``FrozenSet[...]`` / bare ``set``."""
    if isinstance(ann, ast.Subscript):
        ann = ann.value
    name = ann.attr if isinstance(ann, ast.Attribute) else \
        (ann.id if isinstance(ann, ast.Name) else None)
    return name in ("Set", "set", "FrozenSet", "frozenset", "MutableSet",
                    "AbstractSet")


@register
class DeterminismPass(AnalysisPass):
    name = "determinism"
    description = ("golden-pinned modules must not use wall clocks, "
                   "unseeded RNGs, id()/hash() ordering, or unordered-set "
                   "iteration")
    hint = ("golden logs and traces are pinned byte-identical: thread time "
            "through the event clock, use a seeded np.random.RandomState/"
            "default_rng, and iterate sets via sorted(...)")
    # the byte-identical surfaces: Alg. 1-2 + Eq. 1-12 (core/) and the
    # golden-dispatch-log scheduling loop (SchedulerCore)
    targets = ("src/repro/core", "src/repro/serving/core.py")

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        yield from self._check_calls(sf)
        yield from self._check_set_iteration(sf)

    # ------------------------------------------------------------------
    def _check_calls(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted is None:
                continue
            parts = tuple(dotted.split("."))
            # wall clocks / entropy sources
            if parts[-2:] in _CLOCK_CALLS or parts in _CLOCK_CALLS:
                yield self.finding(
                    sf, node.lineno,
                    f"wall-clock/entropy call `{dotted}()` in a "
                    f"golden-pinned module")
                continue
            # global-state randomness: random.*, np.random.*
            if parts[0] == "random" and len(parts) == 2:
                yield self.finding(
                    sf, node.lineno,
                    f"global-RNG call `{dotted}()` — stdlib `random` module "
                    f"state is process-global")
                continue
            if len(parts) >= 3 and parts[-3] in ("np", "numpy") \
                    and parts[-2] == "random" \
                    and parts[-1] not in _SEEDED_CTORS:
                yield self.finding(
                    sf, node.lineno,
                    f"global-RNG call `{dotted}()` — use a seeded "
                    f"RandomState/default_rng instance")
                continue
            if len(parts) >= 2 and parts[-2] == "random" \
                    and parts[-1] in _SEEDED_CTORS and not node.args \
                    and not node.keywords:
                yield self.finding(
                    sf, node.lineno,
                    f"`{dotted}()` constructed without a seed draws OS "
                    f"entropy")
                continue
            # identity / salted-hash ordering
            if dotted in ("id", "hash"):
                yield self.finding(
                    sf, node.lineno,
                    f"`{dotted}()` is run-dependent ({'CPython address' if dotted == 'id' else 'PYTHONHASHSEED-salted'} "
                    f"ordering) in a golden-pinned module")

    # ------------------------------------------------------------------
    def _set_bindings(self, sf: SourceFile) -> Dict[str, int]:
        """Names/attribute-chains statically known to hold sets, mapped to
        the line that bound them (module- and class/function-level
        assignments, annotations included)."""
        assert sf.tree is not None
        known: Dict[str, int] = {}
        for node in ast.walk(sf.tree):
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign) and _is_set_expr(node.value):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                if _is_set_annotation(node.annotation) or (
                        node.value is not None and _is_set_expr(node.value)):
                    targets = [node.target]
            elif isinstance(node, ast.AugAssign):
                continue
            for t in targets:
                name = _dotted(t)
                if name is not None:
                    known[name] = node.lineno
        return known

    def _check_set_iteration(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        known = self._set_bindings(sf)

        def set_like(expr: ast.expr) -> Optional[str]:
            if _is_set_expr(expr):
                return ast.unparse(expr) if len(ast.unparse(expr)) < 40 \
                    else "a set expression"
            name = _dotted(expr)
            if name is not None and name in known:
                return name
            return None

        for node in ast.walk(sf.tree):
            iters: List[Tuple[int, ast.expr]] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append((node.lineno, node.iter))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend((g.iter.lineno, g.iter)
                             for g in node.generators)
            elif isinstance(node, ast.Call):
                fname = node.func.id if isinstance(node.func, ast.Name) \
                    else None
                if fname in _ITER_WRAPPERS and len(node.args) >= 1:
                    iters.append((node.lineno, node.args[0]))
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "pop" and not node.args:
                    tgt = set_like(node.func.value)
                    if tgt is not None:
                        yield self.finding(
                            sf, node.lineno,
                            f"`.pop()` on set `{tgt}` removes an arbitrary "
                            f"element")
            for line, it in iters:
                tgt = set_like(it)
                if tgt is not None:
                    yield self.finding(
                        sf, line,
                        f"iteration over unordered set `{tgt}` — order is "
                        f"insertion/hash dependent")
