"""``docs-refs`` — documentation references resolve against the tree.

The PR 2 docs job (``scripts/check_docs_refs.py``) kept paper_map.md and
architecture.md honest by importing every ``path.py:Symbol`` reference
and stat-ing every local markdown link.  Folded into the analysis
framework, the same check shares the findings format, per-line
suppressions, the baseline mechanism, and the one blocking CI entry
point; the old script remains as a thin shim.

Checked per markdown file (``docs/*.md`` + README.md):

  * ``` `src/repro/x.py:Symbol.attr` ``` — the file exists AND the
    symbol chain imports/getattrs;
  * ``[text](relative/path)`` — the link target exists (URLs and
    ``mailto:`` skipped).
"""
from __future__ import annotations

import importlib
import pathlib
import re
from typing import Iterable, List, Optional

from repro.analysis.framework import AnalysisPass, Finding, SourceFile, register

# `src/repro/core/memory.py:AnalyticMemoryEstimator.kv_bytes` in backticks
REF_RE = re.compile(r"`([\w/.-]+\.py):([A-Za-z_][\w.]*)`")
# [text](local/path.md) — skip URLs and intra-page anchors
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+?)(?:#[^)]*)?\)")


def check_symbol_ref(repo: pathlib.Path, path: str,
                     symbol: str) -> Optional[str]:
    """Returns an error string, or None when the reference resolves."""
    if not (repo / path).is_file():
        return f"file does not exist: {path}"
    p = pathlib.PurePosixPath(path)
    parts = p.with_suffix("").parts
    if parts and parts[0] == "src":
        parts = parts[1:]
    modname = ".".join(parts)
    try:
        mod = importlib.import_module(modname)
    except Exception as e:  # noqa: BLE001 — any import failure is a doc bug
        return f"cannot import {modname}: {e!r}"
    obj = mod
    for attr in symbol.split("."):
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{modname} has no symbol {symbol!r}"
    return None


@register
class DocsRefsPass(AnalysisPass):
    name = "docs-refs"
    description = ("every `path.py:Symbol` reference in the docs imports "
                   "and every local markdown link resolves")
    hint = ("update the reference to the moved/renamed symbol — docs/"
            "paper_map.md and architecture.md are kept import-true")
    targets = ("docs", "README.md")
    suffix = ".md"

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        repo = sf.repo
        for lineno, line in enumerate(sf.lines, start=1):
            for path, symbol in REF_RE.findall(line):
                err = check_symbol_ref(repo, path, symbol)
                if err:
                    yield self.finding(
                        sf, lineno, f"`{path}:{symbol}` — {err}")
            for target in LINK_RE.findall(line):
                if "://" in target or target.startswith("mailto:"):
                    continue
                resolved = (sf.path.parent / target).resolve()
                if not resolved.exists():
                    yield self.finding(
                        sf, lineno, f"broken link -> {target}",
                        hint="the link target moved or was deleted")

    def count_refs(self, sf: SourceFile) -> int:
        """Symbol-reference count (the shim's summary line reports it)."""
        return len(REF_RE.findall(sf.text))
