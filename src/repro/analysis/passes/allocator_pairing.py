"""``allocator-pairing`` — every page acquisition must reach a release.

The PR 3 review found a cancel path that left a ``PageAllocator``
envelope charged forever; the PR 5/7 hypothesis churn suites guard the
same property dynamically.  This pass proves the *shape* of it at lint
time: inside any function over ``engine/``, ``serving/`` and
``cluster/``, a call that acquires pages —

    ``<alloc>.reserve(...)``, ``.extend(...)``, ``.share(...)``,
    ``.fork(...)``

— must not be able to reach a function exit (normal **or** exceptional)
without a matching ``.release(...)`` / ``.shrink(...)`` on an allocator
of the same name, as computed over the statement-level CFG
(:mod:`repro.analysis.cfg`).

Ownership transfers are real in this codebase (retention deliberately
keeps pages alive past the acquiring function — freed later by
``release_request`` / ``finish_batch`` / eviction): annotate those sites
with ``# repro: transfer(allocator-pairing) — <where it is released>``.

Receiver matching is by trailing identifier (``self.alloc``,
``allocator``, ``self.allocators[wid]`` → ``alloc``/``allocator``/
``allocators``) so list methods like ``pool.extend(items)`` never match.

One idiom is blessed beyond what the dataflow can prove: an acquire
enclosed in a ``try`` whose ``finally`` contains a matching release —
even a *conditional* one (the canonical cleanup loop ``for s in slots:
if s.owner >= 0: alloc.release(s.owner)`` releases exactly the residual
set, which is loop-carried state the CFG cannot track).  A function with
no cleanup at all — the PR 3 cancel-path shape — is still flagged.
"""
from __future__ import annotations

import ast
import pathlib
from typing import FrozenSet, Iterable, List, Optional, Sequence

from repro.analysis.cfg import FunctionCFG, reaching
from repro.analysis.framework import AnalysisPass, Finding, SourceFile, register

ACQUIRE_METHODS = frozenset({"reserve", "extend", "share", "fork"})
RELEASE_METHODS = frozenset({"release", "shrink"})
ALLOCATOR_NAMES = frozenset({"alloc", "allocator", "allocators",
                             "page_allocator"})


def _trailing_name(expr: ast.expr) -> Optional[str]:
    """``self.allocators[wid]`` -> ``allocators``; ``alloc`` -> ``alloc``;
    call results -> None (not a stable allocator reference)."""
    if isinstance(expr, ast.Subscript):
        return _trailing_name(expr.value)
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _alloc_calls(stmt: ast.stmt, methods: FrozenSet[str],
                 names: FrozenSet[str]) -> List[ast.Call]:
    """Allocator-method calls in ``stmt``'s *own* expressions.  Child
    statements (a compound statement's body) are separate CFG nodes and
    must not be double-counted here; nested defs/lambdas don't run when
    the statement does."""
    out: List[ast.Call] = []
    stack: List[ast.AST] = [stmt]
    while stack:
        node = stack.pop()
        if node is not stmt and isinstance(
                node, (ast.stmt, ast.Lambda)):
            continue
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in methods \
                    and _trailing_name(node.func.value) in names:
                out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return out


def _subtree_release_names(stmts: Sequence[ast.stmt], methods: FrozenSet[str],
                           names: FrozenSet[str]) -> FrozenSet[str]:
    """Allocator names released anywhere under ``stmts`` (child statements
    included, nested defs/lambdas excluded)."""
    found = set()
    stack: List[ast.AST] = list(stmts)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in methods:
                nm = _trailing_name(node.func.value)
                if nm in names:
                    found.add(nm)
        stack.extend(ast.iter_child_nodes(node))
    return frozenset(found)


@register
class AllocatorPairingPass(AnalysisPass):
    name = "allocator-pairing"
    description = ("PageAllocator reserve/extend/share/fork call sites must "
                   "reach a release/shrink on every exit path (incl. "
                   "exceptions) or carry an ownership-transfer annotation")
    hint = ("pair the acquisition with release()/shrink() on all exit paths "
            "(try/finally or an explicit unwind), or annotate a deliberate "
            "ownership transfer: # repro: transfer(allocator-pairing) — "
            "released in <where>")
    targets = ("src/repro/engine", "src/repro/serving", "src/repro/cluster")

    # injectable for tests / future per-repo config
    acquire_methods: FrozenSet[str] = ACQUIRE_METHODS
    release_methods: FrozenSet[str] = RELEASE_METHODS
    allocator_names: FrozenSet[str] = ALLOCATOR_NAMES

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(sf, node)

    # ------------------------------------------------------------------
    def _check_function(self, sf: SourceFile,
                        func: ast.AST) -> Iterable[Finding]:
        # label every acquire site "<name>@<line>#<i>"; kills are by
        # allocator trailing name, so one release discharges every acquire
        # on a same-named allocator (no alias analysis — see docstring)
        site_labels = {}

        def gen(stmt: ast.stmt) -> FrozenSet[str]:
            labels = []
            for i, call in enumerate(_alloc_calls(
                    stmt, self.acquire_methods, self.allocator_names)):
                name = _trailing_name(call.func.value)  # type: ignore[union-attr]
                label = f"{name}@{call.lineno}#{i}"
                meth = call.func.attr  # type: ignore[union-attr]
                site_labels[label] = (call.lineno, meth, name)
                labels.append(label)
            return frozenset(labels)

        def kill(stmt: ast.stmt) -> FrozenSet[str]:
            released = {_trailing_name(c.func.value)  # type: ignore[union-attr]
                        for c in _alloc_calls(stmt, self.release_methods,
                                              self.allocator_names)}
            if not released:
                return frozenset()
            return frozenset(lb for lb, (_, _, nm) in site_labels.items()
                             if nm in released)

        # seed site_labels so kill() sees every site regardless of
        # worklist visit order
        for stmt in ast.walk(func):
            if isinstance(stmt, ast.stmt):
                gen(stmt)

        cfg = FunctionCFG(func)
        IN = reaching(cfg, gen, kill)
        leaked_ok = IN[cfg.exit_ok]
        leaked_raise = IN[cfg.exit_raise]

        # blessed idiom: an enclosing finally with a matching (possibly
        # conditional) release is trusted cleanup — see module docstring
        finally_regions = []
        for node in ast.walk(func):
            if isinstance(node, ast.Try) and node.finalbody:
                released = _subtree_release_names(
                    node.finalbody, self.release_methods,
                    self.allocator_names)
                if released:
                    finally_regions.append(
                        (node.lineno, getattr(node, "end_lineno",
                                              node.lineno), released))

        def cleaned_up(line: int, name: str) -> bool:
            return any(start <= line <= end and name in released
                       for start, end, released in finally_regions)

        for label in sorted(leaked_ok | leaked_raise,
                            key=lambda lb: site_labels[lb][0]):
            line, meth, name = site_labels[label]
            if cleaned_up(line, name):
                continue
            how = []
            if label in leaked_ok:
                how.append("a normal return")
            if label in leaked_raise:
                how.append("an exception")
            yield self.finding(
                sf, line,
                f"`{name}.{meth}()` may reach {' and '.join(how)} without a "
                f"release()/shrink() on `{name}` "
                f"(in `{getattr(func, 'name', '?')}`)")
