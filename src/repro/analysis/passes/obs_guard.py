"""``obs-guard`` — observability hooks stay behind their enabled flag.

The PR 6 overhead discipline: the scheduler hot path pays exactly one
attribute read + bool test per hook point when observability is off, so
every ``<x>.obs.on_*(...)`` call must be dominated by a check of the
*same* chain's ``.enabled``:

    if self.obs.enabled:
        self.obs.on_dispatch(...)          # guarded — block form

    if not self.core.obs.enabled:
        return                             # guarded — early-exit form
    ...
    self.core.obs.on_admission(...)

This replaces the old string-count assertion in ``tests/test_obs.py``
(``src.count("self.obs.on_") <= src.count("self.obs.enabled")``), which
could not tell *which* site was unguarded, miscounted docstrings, and
never looked outside one module.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from repro.analysis.framework import AnalysisPass, Finding, SourceFile, register


def _chain(expr: ast.expr) -> Optional[str]:
    """Dotted-name string of a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if not isinstance(expr, ast.Name):
        return None
    parts.append(expr.id)
    return ".".join(reversed(parts))


def _enabled_checks(test: ast.expr, *, negated: bool = False) -> List[Tuple[str, bool]]:
    """``(chain, positive)`` pairs provable from an if-test: ``x.enabled``
    -> (x, True); ``not x.enabled`` -> (x, False); ``a and b`` combines."""
    out: List[Tuple[str, bool]] = []
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        for chain, pos in _enabled_checks(test.operand):
            out.append((chain, not pos if not negated else pos))
        return out
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And) \
            and not negated:
        for v in test.values:
            out.extend(_enabled_checks(v))
        return out
    if isinstance(test, ast.Attribute) and test.attr == "enabled":
        chain = _chain(test.value)
        if chain is not None:
            out.append((chain, not negated))
    return out


def _exits_block(stmts: List[ast.stmt]) -> bool:
    """Does this block unconditionally leave the enclosing flow?"""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


@register
class ObsGuardPass(AnalysisPass):
    name = "obs-guard"
    description = ("every `<x>.obs.on_*(...)` hook call must sit behind an "
                   "`if <x>.obs.enabled:` guard (or an early `if not "
                   "<x>.obs.enabled: return`)")
    hint = ("wrap the call: `if <recv>.enabled: <recv>.on_...(...)` — the "
            "disabled hot path must pay only the attribute read + bool test")
    targets = ("src/repro",)

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        for func in ast.walk(sf.tree):
            if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_body(sf, func.body, guarded=set(),
                                            top=True)

    # ------------------------------------------------------------------
    def _hook_calls(self, stmt: ast.stmt) -> List[Tuple[int, str]]:
        """``(line, receiver_chain)`` for obs hook calls inside ``stmt``
        (not descending into nested defs)."""
        found: List[Tuple[int, str]] = []
        stack: List[ast.AST] = [stmt]
        while stack:
            node = stack.pop()
            if node is not stmt and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr.startswith("on_"):
                recv = node.func.value
                # receiver chain must end in `.obs` (self.obs, core.obs, …)
                if (isinstance(recv, ast.Attribute) and recv.attr == "obs") \
                        or (isinstance(recv, ast.Name) and recv.id == "obs"):
                    chain = _chain(recv)
                    if chain is not None:
                        found.append((node.lineno, chain))
            stack.extend(ast.iter_child_nodes(node))
        return found

    def _check_body(self, sf: SourceFile, stmts: List[ast.stmt],
                    guarded: set, top: bool) -> Iterable[Finding]:
        """Walk a statement block, tracking which obs chains are known
        enabled here (block guards + early-exit guards seen so far)."""
        known = set(guarded)
        for stmt in stmts:
            if isinstance(stmt, ast.If):
                checks = _enabled_checks(stmt.test)
                pos = {c for c, p in checks if p}
                neg = {c for c, p in checks if not p}
                # `if x.enabled: <body>` — body runs with x known enabled
                yield from self._check_body(sf, stmt.body, known | pos,
                                            top=False)
                # `if not x.enabled: <orelse>` symmetric
                yield from self._check_body(sf, stmt.orelse, known | neg,
                                            top=False)
                # `if not x.enabled: return` — the rest of THIS block runs
                # with x enabled
                if neg and _exits_block(stmt.body):
                    known |= neg
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # nested scopes are visited by check_file itself
            # other compound statements: recurse into every block with the
            # current knowledge (loops/with/try don't invalidate it)
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if isinstance(sub, list) and sub \
                        and isinstance(sub[0], ast.stmt):
                    yield from self._check_body(sf, sub, known, top=False)
            if isinstance(stmt, ast.Try):
                for h in stmt.handlers:
                    yield from self._check_body(sf, h.body, known, top=False)
                continue
            if hasattr(stmt, "body") and not isinstance(
                    stmt, (ast.Expr, ast.Assign, ast.AugAssign,
                           ast.AnnAssign, ast.Return)):
                continue  # blocks handled above; don't re-scan their calls
            for line, chain in self._hook_calls(stmt):
                if chain not in known:
                    yield self.finding(
                        sf, line,
                        f"`{chain}.on_*` hook call is not guarded by "
                        f"`if {chain}.enabled:`")
