"""Pass registry — importing this package registers every rule.

Adding a pass: create a module here, subclass
:class:`repro.analysis.framework.AnalysisPass`, decorate it with
``@register``, and import the module below.  docs/static_analysis.md
documents the full recipe.
"""
from repro.analysis.passes import (allocator_pairing, api_typing,  # noqa: F401
                                   determinism, docs_refs, obs_guard,
                                   pallas_conventions)

from repro.analysis.passes.allocator_pairing import AllocatorPairingPass
from repro.analysis.passes.api_typing import ApiTypingPass
from repro.analysis.passes.determinism import DeterminismPass
from repro.analysis.passes.docs_refs import DocsRefsPass
from repro.analysis.passes.obs_guard import ObsGuardPass
from repro.analysis.passes.pallas_conventions import PallasConventionsPass

__all__ = [
    "AllocatorPairingPass",
    "ApiTypingPass",
    "DeterminismPass",
    "DocsRefsPass",
    "ObsGuardPass",
    "PallasConventionsPass",
]
