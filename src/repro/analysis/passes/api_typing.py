"""``api-typing`` — the exported serving/kvcache surface is fully typed.

``repro`` ships ``py.typed`` (PR 3), so the public API of the packages
downstream code programs against — ``repro.kvcache`` and
``repro.serving`` — must actually carry annotations.  This pass enforces
what CI's ``mypy --disallow-untyped-defs`` job checks, but at the same
sub-second cost as every other rule and with findings in the shared
``file:line`` + suppression format:

  * every function/method parameter annotated (``self``/``cls`` exempt);
  * every function/method return annotated (``__init__`` exempt — its
    return is always ``None`` and mypy infers it).

All defs in the configured packages are checked, private helpers
included, mirroring ``disallow_untyped_defs``; nested closures are
skipped (mypy infers through them and they are not API).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Sequence, Tuple

from repro.analysis.framework import AnalysisPass, Finding, SourceFile, register


@register
class ApiTypingPass(AnalysisPass):
    name = "api-typing"
    description = ("functions and methods in repro.kvcache / repro.serving "
                   "/ repro.fleet must have fully annotated signatures "
                   "(params + return)")
    hint = ("annotate every parameter and the return type — this package "
            "ships py.typed and CI runs mypy --disallow-untyped-defs on it")
    targets = ("src/repro/kvcache", "src/repro/serving", "src/repro/fleet")

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        yield from self._scan(sf, sf.tree.body, prefix="", method=False)

    def _scan(self, sf: SourceFile, body: Sequence[ast.stmt], prefix: str,
              method: bool) -> Iterable[Finding]:
        for node in body:
            if isinstance(node, ast.ClassDef):
                yield from self._scan(sf, node.body,
                                      prefix=f"{prefix}{node.name}.",
                                      method=True)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_def(sf, node, prefix, method)
                # nested defs are closures, not API — not descended into

    def _check_def(self, sf: SourceFile, fn, prefix: str,
                   method: bool) -> Iterable[Finding]:
        name = f"{prefix}{fn.name}"
        args = fn.args
        ordered = args.posonlyargs + args.args
        missing: List[str] = []
        for i, a in enumerate(ordered):
            if method and i == 0 and a.arg in ("self", "cls"):
                continue
            if a.annotation is None:
                missing.append(a.arg)
        missing.extend(a.arg for a in args.kwonlyargs if a.annotation is None)
        if args.vararg is not None and args.vararg.annotation is None:
            missing.append(f"*{args.vararg.arg}")
        if args.kwarg is not None and args.kwarg.annotation is None:
            missing.append(f"**{args.kwarg.arg}")
        if missing:
            yield self.finding(
                sf, fn.lineno,
                f"`{name}` has unannotated parameter(s): "
                f"{', '.join(missing)}")
        if fn.returns is None and fn.name != "__init__":
            yield self.finding(
                sf, fn.lineno, f"`{name}` has no return annotation")
