"""``pallas-conventions`` — repo conventions for Pallas TPU kernels.

Every kernel in ``kernels/`` follows the same contract (established in
PR 2 and load-bearing ever since: the xla/pallas impl switch in
``ops.py`` is what lets CI validate kernels in interpret mode against
their oracles):

  1. **oracle** — each public kernel entry point ``foo`` in
     ``kernels/foo.py`` has a pure-jnp reference ``foo_ref`` in
     ``kernels/ref.py``;
  2. **dispatch** — ``kernels/ops.py`` imports the kernel, so the
     ``impl={"xla","pallas"}`` switch covers it;
  3. **index maps** — BlockSpec/GridSpec index-map lambdas must not close
     over mutable state (module globals that are reassigned, or locals
     bound to list/dict/set values): they are traced once and cached, so
     a mutated closure silently changes addressing;
  4. **aliasing** — ``input_output_aliases`` keys must be valid operand
     indices of the actual ``pl.pallas_call(...)(...)`` invocation
     (scalar-prefetch args included) and values valid ``out_shape``
     indices;
  5. **no Python branching on traced refs** — ``if``/``while`` on values
     read from ``*_ref`` parameters is a tracer error at best and a
     silent specialization at worst; use ``@pl.when`` / ``jnp.where``.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.framework import (AnalysisPass, Finding, SourceFile,
                                      register)

_NON_KERNEL_FILES = {"__init__.py", "ops.py", "ref.py", "compat.py"}
_MUTABLE_CTORS = {"list", "dict", "set", "bytearray", "deque", "defaultdict"}


def _lambda_free_names(lam: ast.Lambda) -> Set[str]:
    bound = {a.arg for a in (lam.args.posonlyargs + lam.args.args
                             + lam.args.kwonlyargs)}
    if lam.args.vararg:
        bound.add(lam.args.vararg.arg)
    if lam.args.kwarg:
        bound.add(lam.args.kwarg.arg)
    free: Set[str] = set()
    for node in ast.walk(lam.body):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            free.add(node.id)
        elif isinstance(node, ast.Lambda):
            # nested lambda params shadow — rare enough to ignore here
            pass
    import builtins
    return {n for n in free - bound if not hasattr(builtins, n)}


@register
class PallasConventionsPass(AnalysisPass):
    name = "pallas-conventions"
    description = ("kernels declare a jnp oracle in ref.py + a dispatch in "
                   "ops.py; index maps don't close over mutable state; "
                   "input_output_aliases indices are valid; no Python "
                   "branching on traced refs")
    hint = ("see docs/static_analysis.md#pallas-conventions and the "
            "existing kernels for the contract")
    targets = ("src/repro/kernels",)
    kernels_dir = "src/repro/kernels"

    def run(self, repo: pathlib.Path,
            files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        kdir = (repo / self.kernels_dir).resolve()
        kernel_files = [sf for sf in files
                        if sf.path.parent.resolve() == kdir
                        and sf.tree is not None]
        ref_sf = next((sf for sf in kernel_files
                       if sf.path.name == "ref.py"), None)
        ops_sf = next((sf for sf in kernel_files
                       if sf.path.name == "ops.py"), None)
        ref_defs: Set[str] = set()
        if ref_sf is not None and ref_sf.tree is not None:
            ref_defs = {n.name for n in ref_sf.tree.body
                        if isinstance(n, ast.FunctionDef)}
        ops_imports: Set[str] = set()
        if ops_sf is not None and ops_sf.tree is not None:
            for n in ast.walk(ops_sf.tree):
                if isinstance(n, ast.ImportFrom) and n.module:
                    ops_imports.add(n.module)

        for sf in kernel_files:
            if sf.path.name in _NON_KERNEL_FILES:
                continue
            out.extend(self._check_kernel_module(sf, ref_defs, ops_imports))
        for sf in kernel_files:
            if sf.tree is None:
                continue
            out.extend(self._check_index_maps(sf))
            out.extend(self._check_aliases(sf))
            out.extend(self._check_traced_branching(sf))
        return out

    # ------------------------------------------------------------------
    # 1 + 2: oracle in ref.py, dispatch in ops.py
    def _check_kernel_module(self, sf: SourceFile, ref_defs: Set[str],
                             ops_imports: Set[str]) -> Iterable[Finding]:
        assert sf.tree is not None
        mod = sf.path.stem
        entries = [n for n in sf.tree.body if isinstance(n, ast.FunctionDef)
                   and not n.name.startswith("_")]
        if not entries:
            return
        expected_mod = f"repro.kernels.{mod}"
        if expected_mod not in ops_imports:
            yield self.finding(
                sf, 1,
                f"kernel module `{mod}` is not dispatched: ops.py never "
                f"imports `{expected_mod}`",
                hint="add an impl-switched wrapper in kernels/ops.py so the "
                     "xla/pallas toggle covers this kernel")
        for entry in entries:
            if f"{entry.name}_ref" not in ref_defs:
                yield self.finding(
                    sf, entry.lineno,
                    f"kernel entry `{entry.name}` has no jnp oracle "
                    f"`{entry.name}_ref` in kernels/ref.py",
                    hint="every Pallas kernel ships a pure-jnp reference in "
                         "kernels/ref.py — it is the CI correctness oracle")

    # ------------------------------------------------------------------
    # 3: index maps must not close over mutable state
    def _check_index_maps(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        module_assigns: Dict[str, int] = {}
        global_names: Set[str] = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Global):
                global_names.update(node.names)
        for node in sf.tree.body:
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        module_assigns[t.id] = \
                            module_assigns.get(t.id, 0) + 1
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                module_assigns[node.target.id] = \
                    module_assigns.get(node.target.id, 0) + 1

        for func in ast.walk(sf.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            params = {a.arg for a in (func.args.posonlyargs + func.args.args
                                      + func.args.kwonlyargs)}
            mutable_locals: Dict[str, int] = {}
            for stmt in ast.walk(func):
                if isinstance(stmt, ast.Assign):
                    val = stmt.value
                    is_mut = isinstance(val, (ast.List, ast.Dict, ast.Set,
                                              ast.ListComp, ast.DictComp,
                                              ast.SetComp)) or (
                        isinstance(val, ast.Call)
                        and isinstance(val.func, ast.Name)
                        and val.func.id in _MUTABLE_CTORS)
                    if is_mut:
                        for t in stmt.targets:
                            if isinstance(t, ast.Name):
                                mutable_locals[t.id] = stmt.lineno
            for call in ast.walk(func):
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, (ast.Attribute, ast.Name))):
                    continue
                fname = call.func.attr if isinstance(call.func, ast.Attribute)\
                    else call.func.id
                if fname != "BlockSpec":
                    continue
                lambdas = [a for a in list(call.args)
                           + [k.value for k in call.keywords]
                           if isinstance(a, ast.Lambda)]
                for lam in lambdas:
                    for name in sorted(_lambda_free_names(lam)):
                        if name in global_names or \
                                module_assigns.get(name, 0) > 1:
                            yield self.finding(
                                sf, lam.lineno,
                                f"index map closes over module-level "
                                f"mutable/reassigned name `{name}`",
                                hint="index maps are traced once — pass the "
                                     "value through scalar prefetch or bind "
                                     "it as a default arg")
                        elif name in mutable_locals:
                            yield self.finding(
                                sf, lam.lineno,
                                f"index map closes over `{name}`, a local "
                                f"bound to a mutable container "
                                f"(line {mutable_locals[name]})",
                                hint="index maps are traced once — close "
                                     "over immutable ints/shapes only")
                        elif name not in params \
                                and name not in module_assigns \
                                and not self._bound_in(func, name):
                            # unknown free name: imported module attr etc.
                            continue

    @staticmethod
    def _bound_in(func: ast.AST, name: str) -> bool:
        for stmt in ast.walk(func):
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id == name:
                        return True
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                for t in ast.walk(stmt.target):
                    if isinstance(t, ast.Name) and t.id == name:
                        return True
        return False

    # ------------------------------------------------------------------
    # 4: input_output_aliases indices
    def _check_aliases(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None
        for outer in ast.walk(sf.tree):
            # the invocation shape: pl.pallas_call(...)( *operands )
            if not (isinstance(outer, ast.Call)
                    and isinstance(outer.func, ast.Call)):
                continue
            inner = outer.func
            f = inner.func
            fname = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else None)
            if fname != "pallas_call":
                continue
            aliases = next((k.value for k in inner.keywords
                            if k.arg == "input_output_aliases"), None)
            if not isinstance(aliases, ast.Dict):
                continue
            if any(isinstance(a, ast.Starred) for a in outer.args) \
                    or outer.keywords:
                continue  # can't count operands statically
            n_operands = len(outer.args)
            out_shape = next((k.value for k in inner.keywords
                              if k.arg == "out_shape"), None)
            n_out: Optional[int] = None
            if isinstance(out_shape, (ast.List, ast.Tuple)):
                n_out = len(out_shape.elts)
            elif out_shape is not None and isinstance(out_shape, ast.Call):
                n_out = 1
            for k, v in zip(aliases.keys, aliases.values):
                if isinstance(k, ast.Constant) and isinstance(k.value, int) \
                        and not (0 <= k.value < n_operands):
                    yield self.finding(
                        sf, k.lineno,
                        f"input_output_aliases key {k.value} is out of "
                        f"range: the pallas_call invocation passes "
                        f"{n_operands} operand(s)",
                        hint="operand indices count scalar-prefetch args "
                             "first — recount against the actual call")
                if n_out is not None and isinstance(v, ast.Constant) \
                        and isinstance(v.value, int) \
                        and not (0 <= v.value < n_out):
                    yield self.finding(
                        sf, v.lineno,
                        f"input_output_aliases value {v.value} is out of "
                        f"range: out_shape declares {n_out} output(s)")

    # ------------------------------------------------------------------
    # 5: no Python branching on traced refs
    def _check_traced_branching(self, sf: SourceFile) -> Iterable[Finding]:
        assert sf.tree is not None

        def walk_own(root: ast.AST) -> Iterable[ast.AST]:
            """Nodes of this scope only — nested def subtrees excluded."""
            stack: List[ast.AST] = list(ast.iter_child_nodes(root))
            while stack:
                node = stack.pop()
                yield node
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.Lambda)):
                    stack.extend(ast.iter_child_nodes(node))

        def scan(func, inherited: Set[str]) -> Iterable[Finding]:
            params = {a.arg for a in (func.args.posonlyargs + func.args.args
                                      + func.args.kwonlyargs)}
            tainted = set(inherited) | {p for p in params
                                        if p.endswith("_ref")}
            nested = []
            if tainted:
                # two passes: collect taint via assignments first so a use
                # before its (lexically later) def in a loop still counts
                for _ in range(2):
                    for node in walk_own(func):
                        if isinstance(node, ast.Assign):
                            names = {n.id for n in ast.walk(node.value)
                                     if isinstance(n, ast.Name)}
                            if names & tainted:
                                for t in node.targets:
                                    if isinstance(t, ast.Name):
                                        tainted.add(t.id)
                for node in walk_own(func):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        nested.append(node)
                    if isinstance(node, (ast.If, ast.While)):
                        test_names = {n.id for n in ast.walk(node.test)
                                      if isinstance(n, ast.Name)}
                        hit = sorted(test_names & tainted)
                        if hit:
                            kw = "while" if isinstance(node, ast.While) \
                                else "if"
                            yield self.finding(
                                sf, node.lineno,
                                f"Python `{kw}` branches on traced value(s) "
                                f"{', '.join(hit)} derived from a kernel "
                                f"ref",
                                hint="use @pl.when / jnp.where — Python "
                                     "control flow on traced values is a "
                                     "trace-time constant, not a runtime "
                                     "branch")
            else:
                for node in walk_own(func):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        nested.append(node)
            for sub in nested:
                yield from scan(sub, tainted)

        for func in sf.tree.body:
            if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from scan(func, set())
