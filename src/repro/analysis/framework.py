"""Core machinery of the ``repro.analysis`` static-analysis suite.

The repo's correctness story rests on invariants that used to be checked
only dynamically (golden-log bit-exactness, allocator leak tests, the
``self.obs.enabled`` guard discipline).  This module is the shared
skeleton that lets each invariant become a *lint-time* pass:

  * :class:`Finding` — one violation: rule, ``file:line``, message, and a
    fix hint;
  * :class:`SourceFile` — a lazily parsed file (text, lines, AST) plus the
    suppression index built from ``# repro: allow(<rule>)`` comments;
  * :class:`AnalysisPass` — the pass interface: declare target files,
    emit findings; registered via :func:`register`;
  * :func:`run_analysis` — the driver: select rules, collect files, run
    passes, filter suppressed/baselined findings into a
    :class:`AnalysisReport`.

Suppression syntax (both spellings suppress; ``transfer`` documents an
*ownership transfer* for the allocator-pairing rule):

    pages = alloc.reserve(rid, n)  # repro: allow(allocator-pairing) — why

A marker on a ``def``/``class`` header line covers the whole body, so a
function-scoped exception needs one annotation, not one per line.  Accepted
exceptions should carry a one-line justification after the marker.

An optional *baseline* file (``--baseline``) records findings to ignore,
keyed by ``(rule, path, message)`` so they survive unrelated line drift —
useful when adopting a new rule over legacy code incrementally.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# ``# repro: allow(rule-a, rule-b)`` / ``# repro: transfer(rule)``
ALLOW_RE = re.compile(r"repro:\s*(?:allow|transfer)\(([\w\s,*-]+)\)")

_PY_SUFFIX = ".py"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, pinned to a source location."""

    rule: str
    path: str          # repo-relative, posix separators
    line: int          # 1-indexed
    message: str
    hint: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: stable across unrelated line-number drift."""
        return (self.rule, self.path, self.message)

    def render(self, *, with_hint: bool = True) -> str:
        s = f"{self.location}: [{self.rule}] {self.message}"
        if with_hint and self.hint:
            s += f"\n    hint: {self.hint}"
        return s


class SourceFile:
    """A file under analysis: text, lines, lazy AST, suppression index."""

    def __init__(self, repo: pathlib.Path, path: pathlib.Path):
        self.repo = repo
        self.path = path
        try:
            self.rel = path.resolve().relative_to(repo.resolve()).as_posix()
        except ValueError:
            self.rel = path.as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self._tree: Optional[ast.Module] = None
        self._parse_error: Optional[SyntaxError] = None
        self._parsed = False
        self._allow: Optional[Dict[int, Set[str]]] = None
        self._scopes: Optional[List[Tuple[int, int, int]]] = None

    # ------------------------------------------------------------------
    @property
    def is_python(self) -> bool:
        return self.path.suffix == _PY_SUFFIX

    @property
    def tree(self) -> Optional[ast.Module]:
        """The parsed AST, or ``None`` for non-Python / unparsable files
        (the runner reports parse failures as findings of rule ``parse``)."""
        if not self._parsed:
            self._parsed = True
            if self.is_python:
                try:
                    self._tree = ast.parse(self.text)
                except SyntaxError as e:
                    self._parse_error = e
        return self._tree

    @property
    def parse_error(self) -> Optional[SyntaxError]:
        self.tree  # noqa: B018 — force the parse
        return self._parse_error

    # ------------------------------------------------------------------
    @property
    def allow(self) -> Dict[int, Set[str]]:
        """Line number -> set of rule names suppressed on that line."""
        if self._allow is None:
            self._allow = {}
            for i, line in enumerate(self.lines, start=1):
                m = ALLOW_RE.search(line)
                if m:
                    rules = {r.strip() for r in m.group(1).split(",")
                             if r.strip()}
                    self._allow[i] = rules
        return self._allow

    def _scope_headers(self) -> List[Tuple[int, int, int]]:
        """``(start, end, header_line)`` for every def/class scope."""
        if self._scopes is None:
            self._scopes = []
            if self.tree is not None:
                for node in ast.walk(self.tree):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.ClassDef)):
                        end = getattr(node, "end_lineno", node.lineno)
                        self._scopes.append((node.lineno, end, node.lineno))
        return self._scopes

    def suppressed(self, rule: str, line: int) -> bool:
        """True when a ``# repro: allow(rule)`` covers ``line``: either on
        the line itself or on the header line of an enclosing def/class."""
        def hit(at: int) -> bool:
            rules = self.allow.get(at)
            return rules is not None and (rule in rules or "*" in rules)

        if hit(line):
            return True
        for start, end, header in self._scope_headers():
            if start <= line <= end and hit(header):
                return True
        return False


# ---------------------------------------------------------------------------
# pass interface + registry
# ---------------------------------------------------------------------------
class AnalysisPass:
    """Base class for a rule.  Subclasses set ``name``/``description``,
    declare which files they want, and implement ``check_file`` (or
    override ``run`` for cross-file rules)."""

    name: str = ""
    description: str = ""
    hint: str = ""
    # repo-relative roots (dirs walked for *.py) or single files
    targets: Sequence[str] = ("src/repro",)
    suffix: str = _PY_SUFFIX

    def files(self, repo: pathlib.Path) -> List[pathlib.Path]:
        out: List[pathlib.Path] = []
        for t in self.targets:
            p = repo / t
            if p.is_dir():
                out.extend(sorted(p.rglob(f"*{self.suffix}")))
            elif p.is_file():
                out.append(p)
        return out

    def run(self, repo: pathlib.Path,
            files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        for sf in files:
            if sf.is_python and sf.tree is None:
                continue  # parse errors are reported once by the runner
            out.extend(self.check_file(sf))
        return out

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, line: int, message: str,
                hint: Optional[str] = None) -> Finding:
        return Finding(rule=self.name, path=sf.rel, line=line,
                       message=message,
                       hint=self.hint if hint is None else hint)


PASSES: Dict[str, type] = {}


def register(cls: type) -> type:
    """Class decorator adding a pass to the global registry."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"{cls.__name__} has no rule name")
    if name in PASSES:
        raise ValueError(f"duplicate rule name {name!r}")
    PASSES[name] = cls
    return cls


def all_rules() -> List[str]:
    _load_passes()
    return sorted(PASSES)


def _load_passes() -> None:
    # importing the package registers every pass exactly once
    import repro.analysis.passes  # noqa: F401


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AnalysisReport:
    findings: List[Finding]
    n_suppressed: int
    n_baselined: int
    n_files: int
    rules: List[str]

    @property
    def ok(self) -> bool:
        return not self.findings

    def render(self) -> str:
        out = [f.render() for f in self.findings]
        status = "OK" if self.ok else f"{len(self.findings)} finding(s)"
        out.append(f"[repro.analysis] {status} — rules: {', '.join(self.rules)}"
                   f"; {self.n_files} file(s) scanned"
                   f"; {self.n_suppressed} suppressed"
                   + (f"; {self.n_baselined} baselined"
                      if self.n_baselined else ""))
        return "\n".join(out)


def find_repo_root(start: Optional[pathlib.Path] = None) -> pathlib.Path:
    """The repo is the nearest ancestor holding pyproject.toml — from the
    installed package location first, then the working directory."""
    candidates = []
    here = pathlib.Path(__file__).resolve()
    if len(here.parents) >= 4:
        candidates.append(here.parents[3])  # src/repro/analysis/ -> repo
    candidates.append((start or pathlib.Path.cwd()).resolve())
    for c in candidates:
        p = c
        while True:
            if (p / "pyproject.toml").is_file():
                return p
            if p.parent == p:
                break
            p = p.parent
    return candidates[-1]


def load_baseline(path: pathlib.Path) -> Set[Tuple[str, str, str]]:
    data = json.loads(path.read_text())
    return {(e["rule"], e["path"], e["message"]) for e in data["findings"]}


def write_baseline(path: pathlib.Path, findings: Sequence[Finding]) -> None:
    data = {"findings": [{"rule": f.rule, "path": f.path,
                          "message": f.message} for f in findings]}
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def run_analysis(repo: Optional[pathlib.Path] = None,
                 rules: Optional[Sequence[str]] = None,
                 paths: Optional[Sequence[pathlib.Path]] = None,
                 baseline: Optional[Set[Tuple[str, str, str]]] = None,
                 ) -> AnalysisReport:
    """Run the selected rules (default: all) and return the report.

    ``paths`` restricts every pass to files under the given paths (a pass
    whose own target set does not intersect contributes nothing).
    """
    _load_passes()
    repo = (repo or find_repo_root()).resolve()
    names = list(rules) if rules else sorted(PASSES)
    unknown = [n for n in names if n not in PASSES]
    if unknown:
        raise KeyError(f"unknown rule(s): {', '.join(unknown)} "
                       f"(known: {', '.join(sorted(PASSES))})")
    restrict = ([p.resolve() for p in paths] if paths else None)

    cache: Dict[pathlib.Path, SourceFile] = {}

    def source(p: pathlib.Path) -> SourceFile:
        p = p.resolve()
        if p not in cache:
            cache[p] = SourceFile(repo, p)
        return cache[p]

    def in_scope(p: pathlib.Path) -> bool:
        if restrict is None:
            return True
        rp = p.resolve()
        for r in restrict:
            if rp == r or r in rp.parents:
                return True
        return False

    raw: List[Finding] = []
    seen_files: Set[pathlib.Path] = set()
    parse_reported: Set[pathlib.Path] = set()
    for name in names:
        pa = PASSES[name]()
        fs = [source(p) for p in pa.files(repo) if in_scope(p)]
        seen_files.update(sf.path for sf in fs)
        for sf in fs:
            if sf.is_python and sf.parse_error is not None \
                    and sf.path not in parse_reported:
                parse_reported.add(sf.path)
                e = sf.parse_error
                raw.append(Finding(rule="parse", path=sf.rel,
                                   line=e.lineno or 1,
                                   message=f"syntax error: {e.msg}"))
        raw.extend(pa.run(repo, fs))

    findings: List[Finding] = []
    n_sup = n_base = 0
    for f in raw:
        sf = cache.get((repo / f.path).resolve())
        if sf is not None and sf.suppressed(f.rule, f.line):
            n_sup += 1
            continue
        if baseline and f.key in baseline:
            n_base += 1
            continue
        findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return AnalysisReport(findings=findings, n_suppressed=n_sup,
                          n_baselined=n_base, n_files=len(seen_files),
                          rules=names)
