"""repro.analysis — the repo-specific static-analysis suite.

The invariants this codebase runs on — allocator acquire/release pairing
(PR 5/7), the ``self.obs.enabled`` guard discipline (PR 6), golden-log
bit-exactness of the scheduler (PR 3), the Pallas kernel conventions
(PR 2), and a fully typed public serving surface — used to be checked
only dynamically, if at all.  This package proves them at lint time:

  ============================  ===========================================
  rule                          invariant
  ============================  ===========================================
  ``allocator-pairing``         every ``PageAllocator`` acquisition reaches
                                a release on all exit paths (CFG dataflow)
  ``obs-guard``                 every ``*.obs.on_*`` hook call is behind
                                ``if *.obs.enabled:``
  ``determinism``               golden-pinned modules: no wall clocks,
                                unseeded RNGs, id()/hash() ordering, or
                                unordered-set iteration
  ``pallas-conventions``        kernels have a jnp oracle + ops dispatch;
                                clean index maps; valid aliases; no Python
                                branching on traced refs
  ``api-typing``                repro.kvcache / repro.serving signatures
                                fully annotated
  ``docs-refs``                 docs ``path.py:Symbol`` refs + local links
                                resolve (the PR 2 docs job, now a pass)
  ============================  ===========================================

Run it::

    PYTHONPATH=src python -m repro.analysis --all     # CI-blocking form
    python -m repro.analysis --rule obs-guard src/repro/serving
    python scripts/lint_repro.py                      # equivalent shim

Suppress a single accepted exception (with a justification)::

    pages = alloc.reserve(rid, n)  # repro: transfer(allocator-pairing) — why

See docs/static_analysis.md for the rule catalog and how to add a pass.
"""
from repro.analysis.framework import (AnalysisPass, AnalysisReport, Finding,
                                      PASSES, SourceFile, all_rules,
                                      find_repo_root, load_baseline, register,
                                      run_analysis, write_baseline)

__all__ = [
    "AnalysisPass",
    "AnalysisReport",
    "Finding",
    "PASSES",
    "SourceFile",
    "all_rules",
    "find_repo_root",
    "load_baseline",
    "register",
    "run_analysis",
    "write_baseline",
]
