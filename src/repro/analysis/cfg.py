"""A small statement-level control-flow graph for intra-function dataflow.

Built for the ``allocator-pairing`` pass: the question it answers is "can
execution travel from statement A to a function exit without passing
through statement B?", *including exceptional exits* — the PR 3
cancel-path allocator leak was exactly a path the eye missed and a CFG
would not have.

Design choices (deliberately conservative — over-approximating the path
set only ever produces extra findings, never hides one):

  * every statement containing a call, ``raise``, or ``assert`` *may
    raise*: it gets an edge to the innermost enclosing handler chain, and
    — unless some handler is a catch-all (``except:`` / ``except
    Exception`` / ``except BaseException``) — onward to the exceptional
    exit.  Exceptional edges drop the statement's gens but keep its
    kills (an acquire that raises acquired nothing; a raising release is
    a broken allocator, not a leak) — so ``x = alloc.reserve(n)``
    directly followed by ``try/finally: release`` is clean.  The one
    blind spot: a statement that acquires AND then raises in a *later*
    call on the same line (``use(alloc.reserve(n))``) — split such lines;
  * ``finally`` bodies are built once and joined onto both the normal and
    the propagating path (a slight over-approximation of the real
    continuation routing);
  * loops may execute zero times (``while True`` included), so a release
    that only happens inside a loop body does not discharge an acquire
    before it.

Nodes carry their AST statement; :func:`reaching` runs a forward
union/kill dataflow over user-supplied ``gen``/``kill`` labels.
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, FrozenSet, List, Optional, Set

_CATCH_ALL = {"Exception", "BaseException"}


class Node:
    """One CFG node.  ``stmt`` is None for the synthetic entry/exits.
    ``succ`` are fall-through/branch edges (statement completed, its
    gen/kill applied); ``exc_succ`` are exceptional edges (statement did
    not complete — dataflow propagates its IN unchanged)."""

    __slots__ = ("stmt", "succ", "exc_succ", "label")

    def __init__(self, stmt: Optional[ast.stmt], label: str = ""):
        self.stmt = stmt
        self.succ: List["Node"] = []
        self.exc_succ: List["Node"] = []
        self.label = label

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        if self.stmt is None:
            return f"<{self.label}>"
        return f"<{type(self.stmt).__name__}@{self.stmt.lineno}>"


class _Frame:
    """An enclosing ``try`` as seen from inside its body: where a raise
    goes first, and whether it can escape past the handlers."""

    __slots__ = ("handler_entries", "catches_all")

    def __init__(self, handler_entries: List[Node], catches_all: bool):
        self.handler_entries = handler_entries
        self.catches_all = catches_all


class FunctionCFG:
    """CFG of one function body (nested defs are *not* descended into —
    analyze them separately)."""

    def __init__(self, func: ast.AST):
        body = getattr(func, "body", None)
        if body is None:  # pragma: no cover — defensive
            raise TypeError(f"not a function node: {func!r}")
        self.entry = Node(None, "entry")
        self.exit_ok = Node(None, "exit_ok")
        self.exit_raise = Node(None, "exit_raise")
        self.nodes: List[Node] = [self.entry, self.exit_ok, self.exit_raise]
        self._loop_stack: List[tuple] = []   # (header, after)
        self._frames: List[_Frame] = []
        first = self._seq(body, self.exit_ok)
        self.entry.succ.append(first)

    # ------------------------------------------------------------------
    def _node(self, stmt: Optional[ast.stmt], label: str = "") -> Node:
        n = Node(stmt, label)
        self.nodes.append(n)
        return n

    def _raise_targets(self) -> List[Node]:
        """Where control may go when a statement raises: the innermost
        handlers, escaping outward until a catch-all (or the exit)."""
        targets: List[Node] = []
        for frame in reversed(self._frames):
            targets.extend(frame.handler_entries)
            if frame.catches_all:
                return targets
        targets.append(self.exit_raise)
        return targets

    @staticmethod
    def _may_raise(stmt: ast.stmt) -> bool:
        # only this statement's own expressions count: child statements
        # of a compound (try/if/for bodies) are separate CFG nodes with
        # their own exceptional edges, and nested def/lambda bodies don't
        # run when the statement does
        stack: List[ast.AST] = [stmt]
        while stack:
            sub = stack.pop()
            if sub is not stmt and isinstance(
                    sub, (ast.stmt, ast.Lambda)):
                continue
            if isinstance(sub, (ast.Call, ast.Raise, ast.Assert)):
                return True
            stack.extend(ast.iter_child_nodes(sub))
        return False

    # ------------------------------------------------------------------
    def _seq(self, stmts: List[ast.stmt], after: Node) -> Node:
        """Build the chain for ``stmts`` flowing into ``after``; returns
        the entry node of the chain."""
        entry = after
        for stmt in reversed(stmts):
            entry = self._stmt(stmt, entry)
        return entry

    def _stmt(self, stmt: ast.stmt, after: Node) -> Node:
        n = self._node(stmt)
        if isinstance(stmt, (ast.If,)):
            n.succ.append(self._seq(stmt.body, after))
            n.succ.append(self._seq(stmt.orelse, after) if stmt.orelse
                          else after)
        elif isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            self._loop_stack.append((n, after))
            body_entry = self._seq(stmt.body, n)  # back edge to header
            self._loop_stack.pop()
            n.succ.append(body_entry)
            # the loop may run zero times / its condition may turn false
            n.succ.append(self._seq(stmt.orelse, after) if stmt.orelse
                          else after)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            n.succ.append(self._seq(stmt.body, after))
        elif isinstance(stmt, ast.Try):
            n.succ.append(self._build_try(stmt, after))
        elif isinstance(stmt, ast.Return):
            n.succ.append(self.exit_ok)
        elif isinstance(stmt, ast.Raise):
            n.exc_succ.extend(self._raise_targets())
        elif isinstance(stmt, ast.Break):
            if self._loop_stack:
                n.succ.append(self._loop_stack[-1][1])
            else:  # pragma: no cover — invalid python
                n.succ.append(after)
        elif isinstance(stmt, ast.Continue):
            if self._loop_stack:
                n.succ.append(self._loop_stack[-1][0])
            else:  # pragma: no cover — invalid python
                n.succ.append(after)
        else:
            n.succ.append(after)
        if self._may_raise(stmt) and not isinstance(stmt, ast.Raise):
            n.exc_succ.extend(self._raise_targets())
        return n

    def _build_try(self, stmt: ast.Try, after: Node) -> Node:
        # finally body: one instance, on both the normal path and (joined)
        # the propagating path — see module docstring
        if stmt.finalbody:
            fin_entry = self._seq(stmt.finalbody, after)
            fin_exit_entry = self._seq(stmt.finalbody, self.exit_raise)
        else:
            fin_entry = after
            fin_exit_entry = self.exit_raise

        # handlers run under the *outer* frame stack (an exception inside
        # a handler propagates outward, not back into this try)
        handler_entries: List[Node] = []
        catches_all = False
        for h in stmt.handlers:
            handler_entries.append(self._seq(h.body, fin_entry))
            if h.type is None:
                catches_all = True
            elif isinstance(h.type, ast.Name) and h.type.id in _CATCH_ALL:
                catches_all = True
        if stmt.finalbody and not catches_all:
            # an uncaught exception still runs finally before propagating
            handler_entries.append(fin_exit_entry)
            catches_all = True  # routed: _raise_targets must stop here

        self._frames.append(_Frame(handler_entries, catches_all))
        else_entry = self._seq(stmt.orelse, fin_entry) if stmt.orelse \
            else fin_entry
        body_entry = self._seq(stmt.body, else_entry)
        self._frames.pop()
        return body_entry


def reaching(cfg: FunctionCFG,
             gen: Callable[[ast.stmt], FrozenSet[str]],
             kill: Callable[[ast.stmt], FrozenSet[str]],
             ) -> Dict[Node, FrozenSet[str]]:
    """Forward may-dataflow: label sets generated at statements, killed at
    statements, unioned at joins.  Returns IN[] per node — in particular
    ``IN[cfg.exit_ok]`` / ``IN[cfg.exit_raise]`` are the labels that can
    reach a normal / exceptional exit without being killed on the way."""
    IN: Dict[Node, Set[str]] = {n: set() for n in cfg.nodes}
    work = list(cfg.nodes)
    # (pred, exceptional?) — an exceptional edge propagates the pred's
    # IN minus its kills (no gen: an acquire that raised holds nothing;
    # kill applies: a raising release is a broken allocator, not a leak)
    preds: Dict[Node, List[tuple]] = {n: [] for n in cfg.nodes}
    for n in cfg.nodes:
        for s in n.succ:
            preds[s].append((n, False))
        for s in n.exc_succ:
            preds[s].append((n, True))

    def out_norm(n: Node, inset: Set[str]) -> Set[str]:
        if n.stmt is None:
            return set(inset)
        return (inset - kill(n.stmt)) | gen(n.stmt)

    def out_exc(n: Node, inset: Set[str]) -> Set[str]:
        if n.stmt is None:  # pragma: no cover — exits have no out-edges
            return set(inset)
        return inset - kill(n.stmt)

    norm_cur: Dict[Node, Set[str]] = {n: set() for n in cfg.nodes}
    exc_cur: Dict[Node, Set[str]] = {n: set() for n in cfg.nodes}
    while work:
        n = work.pop()
        inset = set()
        for p, exceptional in preds[n]:
            inset |= exc_cur[p] if exceptional else norm_cur[p]
        IN[n] = inset
        new_norm = out_norm(n, inset)
        new_exc = out_exc(n, inset)
        if new_norm != norm_cur[n] or new_exc != exc_cur[n]:
            norm_cur[n] = new_norm
            exc_cur[n] = new_exc
            work.extend(n.succ)
            work.extend(n.exc_succ)
    return {n: frozenset(s) for n, s in IN.items()}
