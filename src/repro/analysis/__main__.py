"""CLI for the static-analysis suite: ``python -m repro.analysis``.

Exit status 0 when every selected rule is clean (suppressed/baselined
findings excluded), 1 otherwise — CI runs ``--all`` as a blocking job.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List, Optional, Sequence

from repro.analysis.framework import (PASSES, all_rules, find_repo_root,
                                      load_baseline, run_analysis,
                                      write_baseline)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static-analysis suite "
                    "(see docs/static_analysis.md)")
    p.add_argument("paths", nargs="*", type=pathlib.Path,
                   help="restrict analysis to these files/directories "
                        "(default: each rule's own target set)")
    p.add_argument("--all", action="store_true",
                   help="run every registered rule (the default when no "
                        "--rule is given; CI uses this spelling)")
    p.add_argument("--rule", action="append", default=[], metavar="NAME",
                   help="run only this rule (repeatable)")
    p.add_argument("--list-rules", action="store_true",
                   help="list registered rules and exit")
    p.add_argument("--repo", type=pathlib.Path, default=None,
                   help="repository root (default: auto-detected)")
    p.add_argument("--baseline", type=pathlib.Path, default=None,
                   help="JSON baseline of findings to ignore")
    p.add_argument("--write-baseline", type=pathlib.Path, default=None,
                   metavar="FILE",
                   help="write current findings to FILE and exit 0 "
                        "(adopting a rule over legacy code incrementally)")
    p.add_argument("--no-hints", action="store_true",
                   help="omit fix hints from the output")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        width = max(len(n) for n in all_rules())
        for name in all_rules():
            print(f"{name:<{width}}  {PASSES[name].description}")
        return 0
    if args.all and args.rule:
        print("error: --all and --rule are mutually exclusive",
              file=sys.stderr)
        return 2
    rules: Optional[List[str]] = args.rule or None
    repo = args.repo or find_repo_root()
    baseline = load_baseline(args.baseline) if args.baseline else None
    try:
        report = run_analysis(repo=repo, rules=rules,
                              paths=args.paths or None, baseline=baseline)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    if args.write_baseline is not None:
        write_baseline(args.write_baseline, report.findings)
        print(f"[repro.analysis] baseline with {len(report.findings)} "
              f"finding(s) written to {args.write_baseline}")
        return 0
    if args.no_hints:
        for f in report.findings:
            print(f.render(with_hint=False))
        print(report.render().splitlines()[-1])
    else:
        print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
