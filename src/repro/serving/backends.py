"""Execution backends for :class:`repro.serving.core.SchedulerCore`.

The scheduling loop (arrival intake → predict → DP batch → offload →
slice dispatch → re-enqueue) lives exactly once, in ``SchedulerCore``;
what *varies* between the discrete-event simulator and the real cluster
is only how a dispatched unit of work turns into a duration and token
outcomes.  That variation is this module's ``Backend`` protocol:

  * :class:`SimBackend` — durations come from a calibrated ground-truth
    latency model (optionally noisy), token outcomes are derived
    analytically from each request's true generation length.  Streamed
    token ids are *synthetic* (the generation indices ``0,1,2,...``,
    synthesized lazily by the handle) so the streaming API behaves
    identically on both backends.
  * :class:`RealBackend` — batches run on real JAX
    :class:`~repro.engine.static_engine.StaticEngine` workers (every FLOP
    real), durations are measured wall time, token outcomes come from the
    model.  With ``kv_layout="paged"`` each worker owns a real
    :class:`~repro.kvcache.PageAllocator`.  The envelope lifetime is the
    ``kv_retain`` policy:

      - ``"slice"`` (default, PR 2 semantics): the ``(L_i + S)`` slice
        envelope is reserved at dispatch and released when the core
        processes the slice-completion event, and the engine re-prefills
        prompt + generated on every reschedule (paper §3.3);
      - ``"request"``: the engines store K/V *in* the pages
        (``StaticEngine.serve_batch_paged``) and keep each in-flight
        request's prefix pages resident across slices — a resumed slice
        remaps its retained pages into the batch block table and
        re-prefills nothing.  Pages are released only on
        finish/cancel (:meth:`finish_request`) or by the engine's
        evict-on-pressure / worker-migration fallback, which re-prefills
        classically so memory safety is unchanged.

Backends are intentionally *stateless about scheduling*: they never see
the pool, the offloader, or the predictor.  A new backend (e.g. an RPC
worker fleet) only has to answer "run this batch" and "the slice is
over".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.estimator import ServingTimeEstimator
from repro.core.memory import MemoryEstimator, PagedMemoryEstimator
from repro.core.request import Batch, Request
from repro.engine.static_engine import EOS_DRIVEN, StaticEngine
from repro.kvcache import PageAllocator

# Per-request outcome dict keys (shared with StaticEngine.serve_batch
# results): tokens, n_valid, invalid, pad, finished.
RequestOutcome = Dict[str, object]


@dataclasses.dataclass
class BatchExecution:
    """What happened to one dispatched slice.

    ``per_request`` is aligned with ``Batch.requests``; ``tokens`` are the
    valid tokens this slice produced for that request — real model tokens
    on the real backend, or ``None`` on the sim backend (sim token ids are
    by definition the generation indices ``0..generated-1``, so streaming
    consumers synthesize them lazily instead of the core materializing
    millions of ints during offline paper-scale replays).
    ``finished`` marks EOS/forced completion as observed by the engine.
    ``reprefill_tokens`` counts tokens prefilled beyond each member's
    first prefill (the §3.3 rescheduling overhead this slice paid) — 0
    for retained residents on the persistent paged path.
    ``prefill_dur`` is the prefill portion of ``duration`` when the
    backend can attribute it (measured wall time on the paged real path,
    the deterministic model split on sim; ``None`` when the fused dense
    engine call makes the phases inseparable) — it feeds the trace's
    prefill/decode sub-spans and is never read by the scheduler.
    """

    duration: float
    steps: int
    early_return: bool
    per_request: List[RequestOutcome]
    reprefill_tokens: int = 0
    prefill_dur: Optional[float] = None
    #: prompt tokens satisfied by cross-request prefix-page sharing this
    #: slice (their prefill was a page-table remap) and the pages those
    #: joins took references on — 0 outside kv_retain="request"
    prefix_hit_tokens: int = 0
    shared_blocks: int = 0


@runtime_checkable
class Backend(Protocol):
    """What a SchedulerCore needs from an execution substrate."""

    #: whether continuous-batching modes (ILS / SCLS-CB) can run here
    supports_continuous: bool

    def run_batch(self, wid: int, batch: Batch,
                  prev_tokens: Sequence[Sequence[int]]) -> BatchExecution:
        """Execute ``batch`` for one slice on worker ``wid``.

        Called at dispatch time; ``prev_tokens`` holds each member's
        previously generated tokens (the SCLS re-prefill input).  The
        returned ``duration`` is *virtual* time — the core schedules the
        completion event, applies token accounting, and re-enqueues
        unfinished requests when it fires.
        """
        ...

    def finish_batch(self, wid: int, batch: Batch) -> None:
        """The slice-completion event for ``batch`` is being processed:
        release any per-slice resources (e.g. the paged KV envelope)."""
        ...

    def finish_request(self, req: Request) -> None:
        """``req`` just went terminal (finished or cancelled): release any
        per-REQUEST resources retained across slices (the persistent
        paged prefix pages under ``kv_retain="request"``).  Must be an
        idempotent no-op when nothing is retained."""
        ...

    def release_session(self, session_id: int) -> None:
        """A multi-turn session closed: release any prefix pages anchored
        for it beyond its requests' lifetimes.  Idempotent no-op when the
        backend retains nothing per session."""
        ...

    def prefill_time(self, req: Request) -> float:
        """Continuous modes: virtual cost of one request's join prefill."""
        ...

    def span_time(self, avg_len: float, span: int, n_running: int) -> float:
        """Continuous modes: virtual cost of ``span`` decode iterations at
        parallelism ``n_running`` and mean cached length ``avg_len``."""
        ...


class SimBackend:
    """Latency-model backend: the discrete-event simulator's physics.

    The scheduler consults its own fitted estimator; *this* backend
    consumes time from the ground-truth profile ``true_lat`` (optionally
    log-normal noisy), so estimation error and its consequences are
    modeled faithfully — exactly the legacy ``ClusterSimulator`` split.
    """

    supports_continuous = True

    def __init__(self, true_lat: ServingTimeEstimator,
                 noise_sigma: float = 0.0, seed: int = 0):
        self.true_lat = true_lat
        self.noise_sigma = float(noise_sigma)
        self.rng = np.random.default_rng(seed)

    def _noise(self) -> float:
        if self.noise_sigma <= 0:
            return 1.0
        return float(self.rng.lognormal(0.0, self.noise_sigma))

    # ------------------------------------------------------------------
    def run_batch(self, wid: int, batch: Batch,
                  prev_tokens: Sequence[Sequence[int]]) -> BatchExecution:
        steps = min(batch.slice_len,
                    max(r.remaining_gen for r in batch.requests))
        t_nominal = self.true_lat.t_serve(batch.size, batch.input_len, steps)
        dur = t_nominal * self._noise()
        # prefill share of the slice, for the trace's sub-spans: the
        # nominal model ratio applied to the single drawn duration.  MUST
        # NOT cost an extra rng draw — the golden dispatch logs pin the
        # noise stream, and observability may not perturb it.
        if t_nominal > 0:
            frac = self.true_lat.t_prefill(batch.size,
                                           batch.input_len) / t_nominal
            prefill_dur = dur * min(max(frac, 0.0), 1.0)
        else:
            prefill_dur = 0.0
        per: List[RequestOutcome] = []
        reprefill = 0
        for r in batch.requests:
            remaining = r.remaining_gen
            gen_now = min(remaining, steps)
            if r.generated > 0:  # §3.3: a reschedule re-prefills everything
                reprefill += r.effective_input_len
            per.append(dict(
                tokens=None,  # sim: synthesized lazily (generation indices)
                n_valid=gen_now,
                invalid=steps - gen_now,
                pad=batch.input_len - r.effective_input_len,
                finished=remaining - gen_now <= 0))
        return BatchExecution(duration=dur, steps=steps,
                              early_return=steps < batch.slice_len,
                              per_request=per,
                              reprefill_tokens=reprefill,
                              prefill_dur=prefill_dur)

    def finish_batch(self, wid: int, batch: Batch) -> None:
        pass  # no per-slice resources in virtual time

    def finish_request(self, req: Request) -> None:
        pass  # no per-request resources in virtual time

    def release_session(self, session_id: int) -> None:
        pass  # no per-session resources in virtual time

    def prefill_time(self, req: Request) -> float:
        return self.true_lat.t_prefill(
            1, req.effective_input_len) * self._noise()

    def span_time(self, avg_len: float, span: int, n_running: int) -> float:
        # Σ_{i=1..span} τ(avg+i, N) ≈ span · τ(avg + span/2, N)
        return span * self.true_lat.tau_decode(
            avg_len + span / 2.0, n_running) * self._noise()


class RealBackend:
    """Real-execution backend: StaticEngine workers, measured wall time.

    One physical host runs all engines, so each worker's timeline is
    virtual — the core advances it by the measured wall time of that
    worker's own batches, which is exactly what N parallel machines would
    observe.  Token outcomes (EOS, invalid, pads) come from the engine.

    ``kv_layout="paged"``: each worker gets a real
    :class:`~repro.kvcache.PageAllocator`.  ``kv_retain`` picks the
    envelope lifetime:

      * ``"slice"`` (default): ``run_batch`` reserves every member's
        ``(L_i + S)`` envelope and ``finish_batch`` releases it — the
        engine stays contiguous-transient and re-prefills on every
        reschedule (PR 2 semantics, a MemoryError means the DP batcher
        violated its own no-OOM constraint);
      * ``"request"``: the engines must be persistent-paged
        (``StaticEngine(kv_layout="paged")``); the backend dispatches
        through ``serve_batch_paged`` so resumed requests keep their
        prefix pages and re-prefill nothing, and pages are released only
        when the core finalizes the request (:meth:`finish_request`) or
        when the engine evicts under pressure.  A request whose next
        slice lands on a *different* worker releases its old worker's
        pages and re-prefills there (retention is per-engine; the
        re-prefill is counted in ``reprefill_tokens``).

    Continuous modes are not supported (the ILS baseline on real JAX
    lives in ``repro.engine.continuous_engine``).
    """

    supports_continuous = False

    def __init__(self, engines: Sequence[StaticEngine],
                 mem: Optional[MemoryEstimator] = None,
                 kv_layout: str = "dense",
                 sched_bucket: int = 1,
                 kv_retain: str = "slice"):
        self.engines = list(engines)
        self.allocators: Optional[List[PageAllocator]] = None
        if kv_retain not in ("slice", "request"):
            raise ValueError(f"unknown kv_retain {kv_retain!r} "
                             f"(expected 'slice' or 'request')")
        self.kv_retain = kv_retain
        self.mem = mem if isinstance(mem, PagedMemoryEstimator) else None
        #: kv_retain="request": worker whose engine retains each rid's pages
        self._engine_of: Dict[int, int] = {}
        #: session_id -> (wid, rid) whose pages are anchored past the
        #: request's lifetime so the next turn's prefix join can hit them
        self._session_anchor: Dict[int, tuple] = {}
        if kv_retain == "request" and kv_layout != "paged":
            raise ValueError("kv_retain='request' needs kv_layout='paged'")
        if kv_layout == "paged":
            if not isinstance(mem, PagedMemoryEstimator):
                raise TypeError("kv_layout='paged' needs a PagedMemoryEstimator")
            if mem.bucket % sched_bucket:
                # fits() admits with mem.bucket over raw lengths, while the
                # slice-start reserve charges the batch input length (est-
                # bucketed); mem.bucket must be a multiple of est.bucket so
                # admission is at least as conservative as the reserve —
                # otherwise a legitimately admitted batch can MemoryError
                raise ValueError(
                    f"PagedMemoryEstimator.bucket ({mem.bucket}) must be a "
                    f"multiple of the estimator bucket ({sched_bucket})")
            if kv_retain == "request":
                for i, e in enumerate(self.engines):
                    if getattr(e, "kv_layout", "dense") != "paged":
                        raise TypeError(
                            f"kv_retain='request' needs persistent-paged "
                            f"engines (StaticEngine(kv_layout='paged')); "
                            f"engine {i} is {getattr(e, 'kv_layout', 'dense')!r}")
                    if e.allocator.page_tokens != mem.page_tokens:
                        raise ValueError(
                            f"engine {i} page_tokens "
                            f"({e.allocator.page_tokens}) != estimator's "
                            f"({mem.page_tokens})")
                    if e.allocator.n_pages < mem.total_blocks:
                        raise ValueError(
                            f"engine {i} pool ({e.allocator.n_pages} pages) "
                            f"smaller than the scheduler's budget "
                            f"({mem.total_blocks}): the batcher would "
                            f"over-admit")
                # the engines' own allocators ARE the slice envelopes here
                self.allocators = [e.allocator for e in self.engines]
            else:
                self.allocators = [PageAllocator(mem.total_blocks,
                                                 mem.page_tokens)
                                   for _ in self.engines]

    # ------------------------------------------------------------------
    def run_batch(self, wid: int, batch: Batch,
                  prev_tokens: Sequence[Sequence[int]]) -> BatchExecution:
        eng = self.engines[wid]
        prompts = [r.prompt for r in batch.requests]
        # gen_len=None → EOS-driven: the engine detects the model's own EOS
        forced = [r.remaining_gen if r.gen_len is not None else EOS_DRIVEN
                  for r in batch.requests]
        if self.kv_retain == "request":
            # worker migration: pages retained elsewhere are unreachable
            # from this engine — release them there, re-prefill here
            for r in batch.requests:
                old = self._engine_of.get(r.rid)
                if old is not None and old != wid:
                    self.engines[old].release_request(r.rid)
                self._engine_of[r.rid] = wid
            res = eng.serve_batch_paged(prompts, batch.slice_len,
                                        [r.rid for r in batch.requests],
                                        forced_gen_lens=forced,
                                        already_generated=list(prev_tokens))
            self._sync_retained_gauge()
        else:
            if self.allocators is not None:
                alloc = self.allocators[wid]
                for r in batch.requests:
                    # slice start: every member holds the batch envelope
                    # L_i + S (rows are padded to the batch input length,
                    # as the engine's per-batch cache is)
                    # the envelope is owned by the dispatch protocol:
                    # SchedulerCore calls finish_batch at slice end
                    # (cancel paths included), which releases every member
                    alloc.reserve(r.rid, batch.input_len + batch.slice_len)  # repro: transfer(allocator-pairing) — finish_batch releases
            res = eng.serve_batch(prompts, batch.slice_len,
                                  forced_gen_lens=forced,
                                  already_generated=list(prev_tokens))
        return BatchExecution(duration=res.wall_time, steps=res.steps,
                              early_return=res.early_return,
                              per_request=list(res.results),
                              reprefill_tokens=res.reprefill_tokens,
                              prefill_dur=res.prefill_time,
                              prefix_hit_tokens=res.prefix_hit_tokens,
                              shared_blocks=res.shared_blocks)

    def finish_batch(self, wid: int, batch: Batch) -> None:
        if self.kv_retain == "request":
            return  # retention: the engine trimmed to the resident prefix
        if self.allocators is not None:
            alloc = self.allocators[wid]
            for r in batch.requests:  # slice end: envelope freed
                alloc.release(r.rid)

    def finish_request(self, req: Request) -> None:
        """Terminal (finished/cancelled): free the retained prefix pages.

        A *completed* request belonging to a session is anchored instead:
        its pages (prompt + answer — exactly the next turn's prefix) stay
        resident, replacing the session's previous anchor.  Anchored pages
        remain LRU-evictable under pool pressure and are dropped for good
        by :meth:`release_session` (or an engine eviction); a *cancelled*
        turn releases immediately like any other request.
        """
        if self.kv_retain != "request":
            return
        wid = self._engine_of.pop(req.rid, None)
        if wid is None:
            return
        sid = getattr(req, "session_id", None)
        if sid is not None and req.done and not req.cancelled:
            old = self._session_anchor.get(sid)
            if old is not None and old[1] != req.rid:
                self.engines[old[0]].release_request(old[1])
            self._session_anchor[sid] = (wid, req.rid)
        else:
            self.engines[wid].release_request(req.rid)
        self._sync_retained_gauge()

    def release_session(self, session_id: int) -> None:
        """Drop the session's anchored prefix pages (idempotent)."""
        anchor = self._session_anchor.pop(session_id, None)
        if anchor is not None:
            self.engines[anchor[0]].release_request(anchor[1])
            self._sync_retained_gauge()

    def batch_affinity(self, batch: Batch) -> Optional[int]:
        """Retention-affinity hint for the offloader's ε-tiebreak: the
        worker whose resident prefix pages cover the most tokens of this
        batch's prompts (``None`` when no worker holds a matching prefix).
        Content-based — it consults each engine's prefix index with the
        members' effective token streams, so it finds session anchors and
        shared system prompts alike."""
        if self.kv_retain != "request":
            return None
        streams = []
        for r in batch.requests:
            if r.prompt is None:
                continue
            gen = r.output_tokens or []
            streams.append(np.concatenate([np.asarray(r.prompt, np.int64),
                                           np.asarray(gen, np.int64)])
                           if gen else np.asarray(r.prompt, np.int64))
        if not streams:
            return None
        best_wid, best_hit = None, 0
        for wid, eng in enumerate(self.engines):
            if not getattr(eng, "prefix_sharing", False):
                continue
            hit = sum(eng._prefix.lookup(s)[1] for s in streams)
            if hit > best_hit:
                best_wid, best_hit = wid, hit
        return best_wid

    def _sync_retained_gauge(self) -> None:
        if self.mem is not None:
            self.mem.retained_blocks = sum(a.used_blocks
                                           for a in self.allocators)

    def free_blocks(self) -> List[int]:
        """Per-worker free KV-block counts (paged layout; ``[]`` when
        dense) — surfaced by the HTTP ``/healthz`` snapshot."""
        if self.allocators is None:
            return []
        return [a.free_blocks for a in self.allocators]

    def obs_snapshot(self) -> Dict[str, int]:
        """KV-pool state for the observability gauges / counter tracks
        (``repro.obs``); ``{}`` on the dense layout, where there is no
        page pool to report."""
        if self.allocators is None:
            return {}
        snap = dict(
            free_pages=sum(a.free_blocks for a in self.allocators),
            evictions=sum(getattr(e, "n_evictions", 0)
                          for e in self.engines))
        if self.kv_retain == "request":
            snap["retained_blocks"] = sum(a.used_blocks
                                          for a in self.allocators)
            snap["shared_blocks"] = sum(a.shared_blocks
                                        for a in self.allocators)
        return snap

    def prefill_time(self, req: Request) -> float:
        raise NotImplementedError(
            "RealBackend does not run continuous modes; use "
            "repro.engine.continuous_engine.ContinuousEngine")

    def span_time(self, avg_len: float, span: int, n_running: int) -> float:
        raise NotImplementedError(
            "RealBackend does not run continuous modes; use "
            "repro.engine.continuous_engine.ContinuousEngine")
