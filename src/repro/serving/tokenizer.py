"""Tokenizer adapters for the HTTP front end's chat endpoint.

This reproduction has no trained tokenizer, but the chat endpoint needs a
*deterministic, invertible* text <-> token-id codec: multi-turn prefix
sharing works by re-submitting the rendered conversation, so the tokens
of an unchanged history must come out bit-identical every time, and
assistant replies must survive a decode -> re-encode round trip.

Two adapters cover every model the stack serves:

* :class:`ByteTokenizer` — one id per UTF-8 byte, offset past the
  reserved control ids (``0`` pad/BOS, ``1`` EOS — the engines' eos_id).
  Needs ``vocab_size >= 258``; fully invertible, so chat history
  re-encoding reproduces the exact prompt tokens the previous turn
  anchored (the prefix-page join finds them).
* :class:`HashTokenizer` — one id per whitespace word via a stable CRC32
  hash (the PR 4 pseudo-tokenizer, now behind the common interface).
  Not invertible — ``decode`` renders space-joined ids — but
  deterministic, so history prefixes still match token-for-token.

``for_vocab`` picks the right one (``None`` for the length-only sim
backend), ``render_chat`` is the fixed chat template both the HTTP layer
and the equivalence tests share.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, List, Optional, Sequence, Union

#: ids below this are reserved: 0 = pad/BOS, 1 = EOS (StaticEngine eos_id)
BYTE_OFFSET = 2
#: smallest vocabulary the byte codec fits in (256 byte ids + reserved)
MIN_BYTE_VOCAB = BYTE_OFFSET + 256


class ByteTokenizer:
    """Invertible byte-level codec: UTF-8 byte ``b`` <-> id ``b + 2``."""

    invertible = True

    def __init__(self, vocab_size: int):
        if vocab_size < MIN_BYTE_VOCAB:
            raise ValueError(f"ByteTokenizer needs vocab_size >= "
                             f"{MIN_BYTE_VOCAB}, got {vocab_size}")
        self.vocab_size = int(vocab_size)

    def encode(self, text: str) -> List[int]:
        return [b + BYTE_OFFSET for b in text.encode("utf-8")]

    def decode(self, ids: Sequence[int]) -> str:
        # ids outside the byte range (reserved controls, model-generated
        # ids past 257) carry no text — drop them rather than corrupt the
        # stream; what remains decodes deterministically
        data = bytes(i - BYTE_OFFSET for i in ids
                     if BYTE_OFFSET <= i < MIN_BYTE_VOCAB)
        return data.decode("utf-8", errors="replace")


class HashTokenizer:
    """One id per whitespace word, CRC32-hashed into the vocabulary.
    Deterministic but lossy: ``decode`` renders space-joined ids."""

    invertible = False

    def __init__(self, vocab_size: int):
        if vocab_size < 1:
            raise ValueError(f"vocab_size must be >= 1, got {vocab_size}")
        self.vocab_size = int(vocab_size)

    def encode(self, text: str) -> List[int]:
        words = text.split() or [text or "?"]
        return [zlib.crc32(w.encode()) % self.vocab_size for w in words]

    def decode(self, ids: Sequence[int]) -> str:
        return "".join(f" {i}" for i in ids)


def for_vocab(vocab_size: int) -> Optional[Union[ByteTokenizer, HashTokenizer]]:
    """The codec for a model vocabulary: byte-level when it fits (real
    backends, invertible), hash fallback for tiny vocabularies, ``None``
    for the length-only sim backend (``vocab_size == 0``)."""
    if vocab_size >= MIN_BYTE_VOCAB:
        return ByteTokenizer(vocab_size)
    if vocab_size > 0:
        return HashTokenizer(vocab_size)
    return None


def render_chat(messages: Sequence[Dict[str, Any]],
                add_generation_prompt: bool = True) -> str:
    """Render OpenAI-style chat ``messages`` into one prompt string.

    The template is deliberately minimal and *prefix-stable*: appending a
    message never rewrites earlier text, so turn N+1's rendered prompt
    extends turn N's character-for-character — the property token-level
    prefix sharing (and the session equivalence tests) depend on.
    """
    parts: List[str] = []
    for i, m in enumerate(messages):
        if not isinstance(m, dict):
            raise ValueError(f"messages[{i}] must be an object, "
                             f"got {type(m).__name__}")
        role, content = m.get("role"), m.get("content")
        if not isinstance(role, str) or not role:
            raise ValueError(f"messages[{i}].role must be a non-empty string")
        if not isinstance(content, str):
            raise ValueError(f"messages[{i}].content must be a string")
        parts.append(f"<|{role}|>\n{content}\n")
    if add_generation_prompt:
        parts.append("<|assistant|>\n")
    return "".join(parts)
