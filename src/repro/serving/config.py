"""One validated configuration object for the whole serving stack.

Before this module, every benchmark/example/CLI call site re-derived the
same wiring by hand: build a latency profile, "profile" it with noise,
fit the Eq. 3/4 estimator, pick a memory estimator, call
``make_strategy``, construct a cluster.  ``ServingConfig`` collapses that
into one dataclass with validation of strategy × kv_layout × predictor ×
backend combinations, ``from_cli()`` / ``from_dict()`` constructors, and
builders that hand back a ready :class:`~repro.serving.server.SliceServer`.

    server = ServingConfig(strategy="scls", workers=4).build_sim()
    server = ServingConfig.from_cli().build_sim()        # launchers
    server = cfg.build_real(engines, sched_est, mem)     # real engines
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimator import (ServingTimeEstimator,
                                  a100_llama13b_hf_profile,
                                  a100_llama13b_profile)
from repro.core.memory import (A100_80GB_AVAILABLE, AnalyticMemoryEstimator,
                               LLAMA2_13B_DELTA, MemoryEstimator,
                               PagedMemoryEstimator, RuleBasedMemoryEstimator)
from repro.core.schedulers import ALL_STRATEGIES, StrategyConfig, make_strategy
from repro.obs import Observability
from repro.predict import PREDICTORS
from repro.serving.backends import RealBackend, SimBackend
from repro.serving.core import CONTINUOUS_MODES, SchedulerCore
from repro.serving.server import SliceServer

#: strategies a RealBackend can drive (no continuous modes on StaticEngine)
SERVABLE_REAL = tuple(
    s for s in ALL_STRATEGIES
    if make_strategy(s).mode not in CONTINUOUS_MODES)

_PRED_STRATEGIES = ("scls-pred", "oracle")


@dataclasses.dataclass
class ServingConfig:
    """Everything needed to stand up a serving stack, in one place."""

    # --- scheduling ---
    strategy: str = "scls"
    backend: str = "sim"                 # "sim" | "real"
    workers: int = 2
    slice_len: int = 128
    max_gen: int = 1024
    fixed_batch_size: int = 12
    gamma: float = 3.0                   # Γ: minimal schedule interval (s)
    lam: float = 0.5                     # λ in Eq. 12
    max_parallel: int = 12               # ILS conservative cap
    ils_span: int = 32
    # --- KV layout (repro.kvcache) ---
    kv_layout: str = "dense"             # "dense" | "paged"
    page_tokens: int = 16
    # Algorithm-1 no-OOM bound (core.batcher.PACKING_MODES): the default
    # "batch-max" is the paper's closed form (and what the golden batch
    # compositions pin); "envelope" charges each member its own
    # blocks_for(L_j + S) — strictly tighter packing on mixed-length
    # batches, needs kv_layout="paged"
    packing: str = "batch-max"           # "batch-max" | "envelope"
    # envelope lifetime on the paged real backend: "slice" reserves and
    # releases per slice (re-prefill every reschedule, §3.3); "request"
    # keeps prefix pages resident in the engines across slices so a
    # resumed slice re-prefills nothing (persistent StaticEngine storage)
    kv_retain: str = "slice"             # "slice" | "request"
    # cross-request COW prefix sharing: on the paged real backend a new
    # request whose token prefix matches another resident's pages joins
    # them refcounted (``PageAllocator.share``) instead of prefilling.
    # No-op on dense layouts and the sim backend; disable to pin the
    # sharing-free baseline.
    prefix_sharing: bool = True
    # --- generation-length prediction (repro.predict) ---
    predictor: Optional[str] = None      # scls-pred/oracle only
    coverage: float = 0.7
    bucket_phi: float = 2.0
    # --- sim backend ---
    noise_sigma: float = 0.0
    seed: int = 0
    # --- real backend model/memory knobs ---
    arch: str = "llama3.2-1b"
    reduced: bool = True
    m_available: float = 256e6
    zeta: float = 0.9
    mem_bucket: int = 8
    # --- workload knobs consumed by launchers (trace replay) ---
    rate: float = 2.0
    duration: float = 15.0
    # --- online front end (repro.serving.{aio,admission,http}) ---
    http_port: Optional[int] = None      # None = no HTTP endpoint
    http_host: str = "127.0.0.1"         # bind host (fleet: several
                                         # instances + router on one box)
    slo_ms: Optional[float] = None       # default per-request SLO (admission)
    time_scale: Optional[float] = None   # sim pacing: virtual s per wall s
    # --- observability (repro.obs) ---
    # built servers always get a metrics registry (GET /metrics) and a
    # decision-audit ring (GET /debug/decisions); Chrome tracing turns on
    # when a --trace-out path is given (launchers export it on shutdown)
    trace_out: Optional[str] = None      # Perfetto-loadable trace.json path
    audit_capacity: int = 4096           # decision ring size (0 = no audit)

    def __post_init__(self) -> None:
        self.validate()

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Reject invalid strategy × kv_layout × predictor × backend combos
        with actionable messages (called from ``__post_init__``)."""
        if self.strategy not in ALL_STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"choose from {ALL_STRATEGIES}")
        if self.backend not in ("sim", "real"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(expected 'sim' or 'real')")
        if self.kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {self.kv_layout!r} "
                             f"(expected 'dense' or 'paged')")
        if self.predictor is not None:
            if self.predictor not in PREDICTORS:
                raise ValueError(f"unknown predictor {self.predictor!r}; "
                                 f"choose from {tuple(PREDICTORS)}")
            if self.strategy not in _PRED_STRATEGIES:
                raise ValueError(
                    f"predictor={self.predictor!r} needs a prediction-aware "
                    f"strategy ({', '.join(_PRED_STRATEGIES)}); "
                    f"got {self.strategy!r}")
        if self.strategy == "oracle" and self.predictor not in (None, "perfect"):
            raise ValueError(
                "oracle is by definition scls-pred with the perfect "
                f"predictor; predictor={self.predictor!r} contradicts it "
                "(use strategy='scls-pred' for imperfect predictors)")
        if self.backend == "real" and self.strategy not in SERVABLE_REAL:
            raise ValueError(
                f"strategy {self.strategy!r} runs continuous batching, "
                f"which the real backend does not drive (use backend='sim' "
                f"or one of {SERVABLE_REAL})")
        if not 0.0 < self.coverage < 1.0:
            raise ValueError(f"coverage must be in (0, 1), got {self.coverage}")
        if self.workers <= 0:
            raise ValueError(f"need at least one worker, got {self.workers}")
        if self.slice_len <= 0 or self.max_gen <= 0:
            raise ValueError("slice_len and max_gen must be positive")
        # --page-tokens is the block-rounding unit of the whole paged
        # subsystem (core.memory.blocks_for); a non-integer or < 1 value
        # only surfaced later as an opaque shape/indexing failure deep in
        # the allocator or kernels — reject it here with the fix spelled
        # out instead
        if isinstance(self.page_tokens, bool) \
                or not isinstance(self.page_tokens, int):
            raise ValueError(f"page_tokens must be an integer number of "
                             f"cache slots per KV block, got "
                             f"{self.page_tokens!r}")
        if self.page_tokens < 1:
            raise ValueError(f"page_tokens must be >= 1, "
                             f"got {self.page_tokens}")
        if self.kv_retain not in ("slice", "request"):
            raise ValueError(f"unknown kv_retain {self.kv_retain!r} "
                             f"(expected 'slice' or 'request')")
        if self.packing not in ("batch-max", "envelope"):
            raise ValueError(f"unknown packing {self.packing!r} "
                             f"(expected 'batch-max' or 'envelope')")
        if self.packing == "envelope" and self.kv_layout != "paged":
            raise ValueError(
                "packing='envelope' charges per-request block envelopes, "
                "which only a paged block pool can account exactly; use "
                "kv_layout='paged' (--kv-layout paged) or the default "
                "batch-max bound")
        if self.kv_retain == "request":
            if self.kv_layout != "paged":
                raise ValueError(
                    "kv_retain='request' keeps prefix pages resident in "
                    "the engines, which needs kv_layout='paged'")
            if self.backend != "real":
                raise ValueError(
                    "kv_retain='request' is an engine-storage policy; the "
                    "sim backend has no engine storage (use backend='real')")
        if self.bucket_phi <= 1.0:
            raise ValueError(f"bucket_phi must be > 1, got {self.bucket_phi}")
        if self.http_port is not None and not 0 <= self.http_port <= 65535:
            raise ValueError(f"http_port must be in [0, 65535] (0 = "
                             f"ephemeral), got {self.http_port}")
        if not isinstance(self.http_host, str) or not self.http_host.strip():
            raise ValueError(f"http_host must be a non-empty bind host, "
                             f"got {self.http_host!r}")
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be positive, got {self.slo_ms}")
        if self.time_scale is not None:
            if self.time_scale <= 0:
                raise ValueError(f"time_scale must be positive, "
                                 f"got {self.time_scale}")
            if self.backend != "sim":
                raise ValueError(
                    "time_scale paces virtual time, which only the sim "
                    "backend has; the real backend's engines consume wall "
                    "time already")
        if self.audit_capacity < 0:
            raise ValueError(f"audit_capacity must be >= 0 (0 disables "
                             f"the decision audit), got {self.audit_capacity}")
        if self.trace_out is not None and not str(self.trace_out).strip():
            raise ValueError("trace_out must be a non-empty path "
                             "(or None to disable tracing)")

    # ------------------------------------------------------------------
    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ServingConfig":
        """Construct from a plain mapping; unknown keys are an error."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - fields)
        if unknown:
            raise ValueError(f"unknown ServingConfig keys: {unknown}")
        return cls(**dict(d))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def add_cli_args(cls, ap: argparse.ArgumentParser) -> None:
        """Register the shared serving flags on an existing parser."""
        ap.add_argument("--strategy", default=cls.strategy,
                        choices=ALL_STRATEGIES)
        ap.add_argument("--backend", default=cls.backend,
                        choices=["sim", "real"])
        ap.add_argument("--workers", type=int, default=cls.workers)
        ap.add_argument("--slice-len", type=int, default=cls.slice_len)
        ap.add_argument("--max-gen", type=int, default=cls.max_gen)
        ap.add_argument("--fixed-batch-size", type=int,
                        default=cls.fixed_batch_size)
        ap.add_argument("--gamma", type=float, default=cls.gamma)
        ap.add_argument("--max-parallel", type=int, default=cls.max_parallel)
        ap.add_argument("--kv-layout", default=cls.kv_layout,
                        choices=["dense", "paged"],
                        help="worker KV layout (repro.kvcache): paged "
                             "reserves slice envelopes block by block")
        ap.add_argument("--page-tokens", type=int, default=cls.page_tokens,
                        help="cache slots per KV block for --kv-layout paged")
        ap.add_argument("--packing", default=cls.packing,
                        choices=["batch-max", "envelope"],
                        help="Algorithm-1 no-OOM bound: 'batch-max' "
                             "charges every batch member the longest "
                             "member's (L_i + S) envelope (paper default); "
                             "'envelope' charges each member its own "
                             "block envelope — tighter packing, needs "
                             "--kv-layout paged")
        ap.add_argument("--kv-retain", default=cls.kv_retain,
                        choices=["slice", "request"],
                        help="paged real backend: 'slice' releases each "
                             "member's envelope at slice end (re-prefill "
                             "on reschedule); 'request' keeps prefix pages "
                             "resident in the engines so resumed slices "
                             "re-prefill nothing")
        ap.add_argument("--no-prefix-sharing", dest="prefix_sharing",
                        action="store_false", default=cls.prefix_sharing,
                        help="disable COW prefix-page sharing on the paged "
                             "real backend (multi-turn sessions and shared "
                             "prompts then re-prefill their history)")
        ap.add_argument("--predictor", default=None, choices=list(PREDICTORS),
                        help="length predictor for --strategy scls-pred")
        ap.add_argument("--coverage", type=float, default=cls.coverage,
                        help="calibration target quantile for predicted caps")
        ap.add_argument("--noise-sigma", type=float, default=cls.noise_sigma)
        ap.add_argument("--seed", type=int, default=cls.seed)
        ap.add_argument("--arch", default=cls.arch)
        ap.add_argument("--reduced", action="store_true", default=cls.reduced)
        ap.add_argument("--rate", type=float, default=cls.rate)
        ap.add_argument("--duration", type=float, default=cls.duration)
        ap.add_argument("--http-port", type=int, default=cls.http_port,
                        help="serve an OpenAI-compatible HTTP endpoint on "
                             "this port (0 = ephemeral) instead of the "
                             "trace-replay demo")
        ap.add_argument("--http-host", default=cls.http_host,
                        help="bind host for --http-port (default "
                             "127.0.0.1; several instances plus the fleet "
                             "router share one box by port)")
        ap.add_argument("--slo-ms", type=float, default=cls.slo_ms,
                        help="default per-request SLO for admission control "
                             "(requests predicted to miss it get 429)")
        ap.add_argument("--time-scale", type=float, default=cls.time_scale,
                        help="sim-backend pacing: virtual seconds served "
                             "per wall second (1 = real time; default: "
                             "as fast as possible)")
        ap.add_argument("--trace-out", default=cls.trace_out,
                        metavar="TRACE_JSON",
                        help="record a Chrome trace (Perfetto-loadable) of "
                             "the run and write it here on shutdown; the "
                             "decision audit is dumped next to it as "
                             "*.decisions.json")
        ap.add_argument("--audit-capacity", type=int,
                        default=cls.audit_capacity,
                        help="scheduler decision-audit ring size "
                             "(GET /debug/decisions; 0 disables)")

    @classmethod
    def from_cli(cls, argv: Optional[Sequence[str]] = None,
                 description: str = "SCLS serving stack",
                 **defaults: Any) -> "ServingConfig":
        """Parse the shared serving flags into a validated config.

        ``defaults`` override the dataclass defaults (launchers pick their
        own demo-scale values) but never a flag the user actually passed.
        """
        ap = argparse.ArgumentParser(description=description)
        cls.add_cli_args(ap)
        if defaults:
            unknown = sorted(set(defaults)
                             - {f.name for f in dataclasses.fields(cls)})
            if unknown:
                raise ValueError(f"unknown ServingConfig defaults: {unknown}")
            ap.set_defaults(**defaults)
        args = vars(ap.parse_args(argv))
        try:
            return cls.from_dict(args)
        except ValueError as e:
            ap.error(str(e))
            raise  # unreachable; keeps type checkers honest

    # ------------------------------------------------------------------
    # builders
    # ------------------------------------------------------------------
    def observability(self) -> Observability:
        """The ``repro.obs`` bundle for built servers: metrics + decision
        audit always (both are cheap and observation-only — the golden
        dispatch logs are asserted bit-exact with them on), Chrome tracing
        only when ``trace_out`` is set."""
        return Observability.standard(trace=self.trace_out is not None,
                                      audit_capacity=self.audit_capacity)

    def strategy_config(self) -> StrategyConfig:
        return make_strategy(self.strategy, slice_len=self.slice_len,
                             max_gen=self.max_gen,
                             fixed_batch_size=self.fixed_batch_size,
                             gamma=self.gamma, lam=self.lam,
                             max_parallel=self.max_parallel,
                             predictor=self.predictor or "histogram",
                             coverage=self.coverage,
                             bucket_phi=self.bucket_phi,
                             kv_layout=self.kv_layout,
                             packing=self.packing)

    def memory_estimator(self, delta_bytes: float,
                         m_available: Optional[float] = None
                         ) -> MemoryEstimator:
        """The memory model matching this config's kv_layout (Eq. 5–9 /
        block pool)."""
        m_ava = self.m_available if m_available is None else m_available
        if self.kv_layout == "paged":
            mem = PagedMemoryEstimator(delta_bytes=delta_bytes,
                                       m_available=m_ava, zeta=self.zeta,
                                       page_tokens=self.page_tokens,
                                       bucket=self.mem_bucket,
                                       kv_retain=self.kv_retain)
            if mem.total_blocks < 1:
                # the downstream failure is an opaque PageAllocator /
                # shape error; name the actual misconfiguration instead
                raise ValueError(
                    f"page_tokens={self.page_tokens} with "
                    f"m_available={m_ava:g} and zeta={self.zeta} yields a "
                    f"zero-block KV pool (block = page_tokens * Δ bytes); "
                    f"lower --page-tokens or raise the memory budget")
            return mem
        return AnalyticMemoryEstimator(delta_bytes=delta_bytes,
                                       m_available=m_ava, zeta=self.zeta,
                                       bucket=self.mem_bucket)

    def build_sim(self, true_lat: Optional[ServingTimeEstimator] = None,
                  sched_est: Optional[ServingTimeEstimator] = None,
                  mem: Optional[MemoryEstimator] = None,
                  engine_profile: str = "ds") -> SliceServer:
        """SliceServer over the discrete-event SimBackend.

        With no estimators given, the full paper testbed is built
        (``default_sim_environment``: A100/LLaMA2-13B profile, fitted
        estimator, DS rule table or HF analytic memory).  Partially
        specified setups stay *self-consistent*: a missing ``sched_est``
        is fitted from the given ``true_lat`` and a missing ``mem``
        defaults to the analytic (or paged) A100 model — never the DS
        rule table, which is only the all-defaults "ds" behavior.
        """
        if true_lat is None and sched_est is None and mem is None:
            true_lat, sched_est, mem = default_sim_environment(
                engine_profile, paged=self.kv_layout == "paged",
                page_tokens=self.page_tokens)
        else:
            if true_lat is None:
                if engine_profile not in _PROFILES:
                    raise ValueError(
                        f"unknown engine profile {engine_profile!r}")
                true_lat = _PROFILES[engine_profile]()
            if sched_est is None:
                sched_est = fitted_estimator(true_lat)
            if mem is None:
                mem = self.memory_estimator(LLAMA2_13B_DELTA,
                                            m_available=A100_80GB_AVAILABLE)
        backend = SimBackend(true_lat, noise_sigma=self.noise_sigma,
                             seed=self.seed)
        core = SchedulerCore(self.strategy_config(), backend, self.workers,
                             sched_est, mem, ils_span=self.ils_span,
                             obs=self.observability())
        return SliceServer(core, default_slo_ms=self.slo_ms,
                           time_scale=self.time_scale)

    def build_real(self, engines: Sequence[Any],
                   sched_est: ServingTimeEstimator,
                   mem: MemoryEstimator) -> SliceServer:
        """SliceServer over real StaticEngine workers (one per engine)."""
        backend = RealBackend(engines, mem=mem, kv_layout=self.kv_layout,
                              sched_bucket=sched_est.bucket,
                              kv_retain=self.kv_retain)
        core = SchedulerCore(self.strategy_config(), backend, len(engines),
                             sched_est, mem, ils_span=self.ils_span,
                             obs=self.observability())
        return SliceServer(core, default_slo_ms=self.slo_ms)

    def build(self, **kwargs: Any) -> SliceServer:
        """Dispatch on ``backend`` (build_real needs engines/sched_est/mem)."""
        if self.backend == "real":
            return self.build_real(**kwargs)
        return self.build_sim(**kwargs)


# ---------------------------------------------------------------------------
# the paper-testbed wiring, centralized (was copy-pasted at ~15 call sites)
# ---------------------------------------------------------------------------
_PROFILES = {"ds": a100_llama13b_profile, "hf": a100_llama13b_hf_profile}


def fitted_estimator(true_lat: ServingTimeEstimator,
                     seed: int = 0) -> ServingTimeEstimator:
    """'Profile' the ground-truth latency model with 2% measurement noise
    and fit Eq. 3/4 — mirrors the paper's one-time profiling step."""
    rng = np.random.default_rng(seed)
    pre = [(N, L, true_lat.t_prefill(N, L) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    return est


def default_sim_environment(
        engine_profile: str = "ds", fit_seed: int = 0, paged: bool = False,
        page_tokens: int = 16,
        ) -> Tuple[ServingTimeEstimator, ServingTimeEstimator,
                   MemoryEstimator]:
    """(ground-truth latency, fitted scheduler estimator, memory model)
    for the paper's A100/LLaMA2-13B testbed.

    ``engine_profile``: "ds" (DeepSpeed; Algorithm 2 rule table) or "hf"
    (HuggingFace; Eq. 5–9 analytic model), as in §5.1.
    """
    if engine_profile not in _PROFILES:
        raise ValueError(f"unknown engine profile {engine_profile!r} "
                         f"(expected one of {tuple(_PROFILES)})")
    true_lat = _PROFILES[engine_profile]()
    est = fitted_estimator(true_lat, seed=fit_seed)
    mem: MemoryEstimator
    if paged:
        mem = PagedMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                   m_available=A100_80GB_AVAILABLE,
                                   zeta=0.9, page_tokens=page_tokens)
    elif engine_profile == "ds":
        mem = RuleBasedMemoryEstimator()
    else:
        mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                      m_available=A100_80GB_AVAILABLE,
                                      zeta=0.9)
    return true_lat, est, mem
