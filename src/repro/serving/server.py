"""Request-level online serving API over one :class:`SchedulerCore`.

The offline runtimes take a fully pre-materialized trace and a duration;
``SliceServer`` is what a real SCLS deployment needs instead: requests
are *submitted* while the system runs, their tokens are observable per
slice as they are produced, and they can be cancelled mid-flight.

    server = ServingConfig(strategy="scls", workers=4).build_sim()
    h = server.submit(input_len=64, gen_len=200)
    for tok in h.tokens():          # streams per-slice, driving the core
        ...
    h2 = server.submit(input_len=32, gen_len=500)
    h2.cancel()                     # frees its page envelope mid-flight
    server.drain()                  # completes all in-flight work

Time is virtual on both backends (the real backend measures wall time per
batch but keeps per-worker virtual clocks), so the server is a
*synchronous* reactor: every ``tokens()`` / ``result()`` / ``drain()``
call advances the shared event queue.  Online arrivals enter the exact
same batching/offloading algorithms (Alg. 1–2) the offline path uses —
there is no second scheduler.
"""
from __future__ import annotations

import itertools
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.cluster.metrics import RunMetrics
from repro.core.request import Request
from repro.serving.core import SchedulerCore


class RequestHandle:
    """Live view of one submitted request."""

    def __init__(self, server: "SliceServer", request: Request):
        self._server = server
        self.request = request

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def finished(self) -> bool:
        """Terminal (completed or cancelled)."""
        return self._server.core.is_finalized(self.rid)

    @property
    def done(self) -> bool:
        """Completed successfully."""
        return self.finished and self.request.done

    @property
    def cancelled(self) -> bool:
        return self.request.cancelled

    def _tokens_so_far(self) -> Sequence[int]:
        toks = self._server.core.token_log.get(self.rid)
        if toks is not None:  # real backend, mid-flight
            return toks
        if self.finished and self.request.output_tokens is not None:
            return self.request.output_tokens  # real backend, terminal
        # sim backend: token ids are by definition the generation indices
        return range(self.request.generated)

    @property
    def output_tokens(self) -> List[int]:
        """Tokens produced so far (all of them once terminal)."""
        return list(self._tokens_so_far())

    def tokens(self) -> Iterator[int]:
        """Stream this request's tokens as slices complete.

        Tokens materialize at slice boundaries (a slice is the atom of
        scheduling); the iterator advances the server's event queue while
        it waits, so consuming it also serves everything else in flight.
        On the sim backend token ids are synthetic generation indices.
        """
        cursor = 0
        while True:
            toks = self._tokens_so_far()
            while cursor < len(toks):
                yield toks[cursor]
                cursor += 1
            if self.finished:
                return
            if not self._server.core.step():  # same contract as result()
                raise RuntimeError(
                    f"request {self.rid} cannot make progress: the event "
                    f"queue is empty but it never finalized")

    def result(self) -> Request:
        """Drive the server until this request is terminal; returns the
        finalized :class:`Request` (tokens in ``output_tokens``)."""
        while not self.finished:
            if not self._server.core.step():
                raise RuntimeError(
                    f"request {self.rid} cannot make progress: the event "
                    f"queue is empty but it never finalized")
        return self.request

    def cancel(self) -> bool:
        """Cancel this request — see :meth:`SchedulerCore.cancel`."""
        return self._server.cancel(self.rid)


#: server-assigned request ids live in their own namespace so interactive
#: ``submit`` calls never collide with trace rids (0..n) fed to ``replay``
_SERVER_RID_BASE = 1 << 32


class SliceServer:
    """Submit / stream / cancel front end over one shared SchedulerCore."""

    def __init__(self, core: SchedulerCore):
        self.core = core
        self._next_rid = itertools.count(_SERVER_RID_BASE)
        self._handles: dict[int, RequestHandle] = {}
        self._closed = False

    # ------------------------------------------------------------------
    @property
    def strategy(self):
        return self.core.s

    @property
    def now(self) -> float:
        return self.core.now

    # ------------------------------------------------------------------
    def submit(self, prompt: Optional[np.ndarray] = None, *,
               input_len: Optional[int] = None,
               gen_len: Optional[int] = None,
               max_gen: int = 1024,
               arrival: Optional[float] = None) -> RequestHandle:
        """Submit one request; returns a handle immediately.

        ``prompt`` (token ids) is required on the real backend and
        optional on the sim backend (``input_len`` suffices there).
        ``gen_len`` emulates a known EOS position — the repo-wide
        controlled-replay convention; pass None to decode until the
        model's own EOS (real backend) or ``max_gen`` (sim backend).
        ``arrival`` defaults to the server's current virtual time.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        if prompt is None and input_len is None:
            raise ValueError("need a prompt or an input_len")
        if prompt is not None:
            prompt = np.asarray(prompt, np.int32)
            if input_len is None:
                input_len = int(prompt.shape[0])
        rid = next(self._next_rid)
        while rid in self.core._by_rid:  # replay() may have taken ids
            rid = next(self._next_rid)
        req = Request(rid=rid, arrival=self.core.now, input_len=int(input_len),
                      gen_len=None if gen_len is None else int(gen_len),
                      max_gen=int(max_gen), prompt=prompt)
        self.core.submit(req, arrival=arrival)
        h = RequestHandle(self, req)
        self._handles[rid] = h
        return h

    def replay(self, requests: Sequence[Request]) -> List[RequestHandle]:
        """Submit pre-built trace requests (mutated in place, like the
        legacy ``run()`` path — deep-copy the trace to keep it)."""
        if self._closed:
            raise RuntimeError("server is closed")
        handles = []
        for r in requests:
            self.core.submit(r)
            h = RequestHandle(self, r)
            self._handles[r.rid] = h
            handles.append(h)
        return handles

    # ------------------------------------------------------------------
    def cancel(self, rid: int) -> bool:
        return self.core.cancel(rid)

    def step(self) -> bool:
        """Advance the shared event queue by one event."""
        return self.core.step()

    def drain(self, duration: Optional[float] = None) -> RunMetrics:
        """Complete all in-flight work; returns the run metrics so far."""
        self.core.run_until_idle()
        return self.core.metrics(duration)

    def metrics(self, duration: Optional[float] = None) -> RunMetrics:
        return self.core.metrics(duration)

    def close(self, duration: Optional[float] = None) -> RunMetrics:
        """Drain and refuse further submissions."""
        m = self.drain(duration)
        self._closed = True
        return m

    # ------------------------------------------------------------------
    def __enter__(self) -> "SliceServer":
        return self

    def __exit__(self, *exc) -> None:
        if exc == (None, None, None):
            self.close()
        # on error, don't mask it by draining
