"""Synchronous request-level serving API over one :class:`SchedulerCore`.

``SliceServer`` is the caller-driven flavor of the online API: every
``tokens()`` / ``result()`` / ``drain()`` call advances the shared event
queue, which makes it deterministic and perfect for tests, offline
replays, and single-client scripts::

    server = ServingConfig(strategy="scls", workers=4).build_sim()
    h = server.submit(input_len=64, gen_len=200)
    for tok in h.tokens():          # streams per-slice, driving the core
        ...
    h2 = server.submit(input_len=32, gen_len=500)
    h2.cancel()                     # frees its page envelope mid-flight
    server.drain()                  # completes all in-flight work

Since PR 4 it is a thin adapter over
:class:`~repro.serving.aio.AsyncSliceServer` (exposed as ``.aio``): the
submission path — validation, rid allocation, SLO-aware admission
(``slo_ms=`` raises :class:`~repro.serving.admission.AdmissionRejected`
before any prefill/page work), handle bookkeeping — lives exactly once in
the async server, and this class only adds the synchronous drive loop.
For N concurrent clients, wall-clock pacing, or the OpenAI-compatible
HTTP endpoint, use ``server.aio`` (``repro.serving.aio``) directly.

Online arrivals enter the exact same batching/offloading algorithms
(Alg. 1–2) the offline path uses — there is no second scheduler.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.cluster.metrics import RunMetrics
from repro.core.request import Request
from repro.core.schedulers import StrategyConfig
from repro.serving.admission import AdmissionController
from repro.serving.aio import (_SERVER_RID_BASE, AsyncSliceServer,
                               RequestView)
from repro.serving.core import SchedulerCore

__all__ = ["RequestHandle", "SliceServer", "_SERVER_RID_BASE"]


class RequestHandle(RequestView):
    """Live view of one submitted request (synchronous drive methods)."""

    def tokens(self) -> Iterator[int]:
        """Stream this request's tokens as slices complete.

        Tokens materialize at slice boundaries (a slice is the atom of
        scheduling); the iterator advances the server's event queue while
        it waits, so consuming it also serves everything else in flight.
        On the sim backend token ids are synthetic generation indices.
        """
        cursor = 0
        while True:
            toks = self._tokens_so_far()
            while cursor < len(toks):
                yield toks[cursor]
                cursor += 1
            if self.finished:
                return
            if not self._server.core.step():  # same contract as result()
                raise RuntimeError(
                    f"request {self.rid} cannot make progress: the event "
                    f"queue is empty but it never finalized")

    def result(self) -> Request:
        """Drive the server until this request is terminal; returns the
        finalized :class:`Request` (tokens in ``output_tokens``)."""
        while not self.finished:
            if not self._server.core.step():
                raise RuntimeError(
                    f"request {self.rid} cannot make progress: the event "
                    f"queue is empty but it never finalized")
        return self.request

    def cancel(self) -> bool:
        """Cancel this request — see :meth:`SchedulerCore.cancel`."""
        return self._server.cancel(self.rid)


class SliceServer:
    """Submit / stream / cancel front end over one shared SchedulerCore.

    Thin synchronous adapter over :class:`AsyncSliceServer` (``.aio``):
    submission/admission/bookkeeping are delegated; only the drive loop
    (``step`` / blocking ``drain``) is this class's own.
    """

    def __init__(self, core: SchedulerCore,
                 admission: Optional[AdmissionController] = None,
                 default_slo_ms: Optional[float] = None,
                 time_scale: Optional[float] = None):
        self.core = core
        #: the concurrent front end this server adapts; share it with
        #: asyncio clients or the HTTP endpoint for the same scheduler
        self.aio = AsyncSliceServer(core, admission=admission,
                                    default_slo_ms=default_slo_ms,
                                    time_scale=time_scale)
        self._handles: dict[int, RequestHandle] = {}

    # ------------------------------------------------------------------
    @property
    def strategy(self) -> StrategyConfig:
        return self.core.s

    @property
    def now(self) -> float:
        return self.core.now

    @property
    def n_rejected(self) -> int:
        return self.core.n_rejected

    @property
    def admission_stats(self) -> dict:
        return self.aio.admission_stats

    # ------------------------------------------------------------------
    def submit(self, prompt: Optional[np.ndarray] = None, *,
               input_len: Optional[int] = None,
               gen_len: Optional[int] = None,
               max_gen: int = 1024,
               arrival: Optional[float] = None,
               slo_ms: Optional[float] = None,
               deadline: Optional[float] = None,
               allow_degrade: bool = False) -> RequestHandle:
        """Submit one request; returns a handle immediately.

        ``prompt`` (token ids) is required on the real backend and
        optional on the sim backend (``input_len`` suffices there).
        ``gen_len`` emulates a known EOS position — the repo-wide
        controlled-replay convention; pass None to decode until the
        model's own EOS (real backend) or ``max_gen`` (sim backend).
        ``arrival`` defaults to the server's current virtual time.
        ``slo_ms``/``deadline`` enable SLO-aware admission: a request
        whose predicted completion violates the deadline raises
        :class:`~repro.serving.admission.AdmissionRejected` before any
        prefill or page reservation (see :meth:`AsyncSliceServer.submit`).
        """
        ah = self.aio.submit(prompt, input_len=input_len, gen_len=gen_len,
                             max_gen=max_gen, arrival=arrival, slo_ms=slo_ms,
                             deadline=deadline, allow_degrade=allow_degrade)
        h = RequestHandle(self, ah.request)
        self._handles[h.rid] = h
        return h

    def replay(self, requests: Sequence[Request]) -> List[RequestHandle]:
        """Submit pre-built trace requests (mutated in place, like the
        legacy ``run()`` path — deep-copy the trace to keep it)."""
        handles = []
        for ah in self.aio.replay(requests):
            h = RequestHandle(self, ah.request)
            self._handles[h.rid] = h
            handles.append(h)
        return handles

    # ------------------------------------------------------------------
    def cancel(self, rid: int) -> bool:
        return self.aio.cancel(rid)

    def step(self) -> bool:
        """Advance the shared event queue by one event."""
        return self.core.step()

    def drain(self, duration: Optional[float] = None) -> RunMetrics:
        """Complete all in-flight work; returns the run metrics so far."""
        self.core.run_until_idle()
        return self.core.metrics(duration)

    def metrics(self, duration: Optional[float] = None) -> RunMetrics:
        return self.core.metrics(duration)

    def close(self, duration: Optional[float] = None) -> RunMetrics:
        """Drain and refuse further submissions."""
        m = self.drain(duration)
        self.aio._closed = True
        return m

    # ------------------------------------------------------------------
    def __enter__(self) -> "SliceServer":
        return self

    def __exit__(self, *exc: object) -> None:
        if exc == (None, None, None):
            self.close()
        # on error, don't mask it by draining
