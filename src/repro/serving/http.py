"""OpenAI-compatible HTTP front end over :class:`AsyncSliceServer`.

Stdlib only (``http.server`` + threads on the wire side, the server's own
asyncio loop on the scheduling side) — no new dependencies:

  * ``POST /v1/completions`` — OpenAI completions shape: ``prompt``
    (string, token-id list, or an integer input length), ``max_tokens``,
    ``stream``; extensions: ``slo_ms`` (SLO-aware admission) and
    ``allow_degrade`` (admit with a shorter budget instead of rejecting).
    ``stream=true`` emits Server-Sent Events with **one chunk per
    completed slice** — the slice is the scheduling atom, so chunk
    boundaries are exactly the moments tokens actually materialize.
  * ``POST /v1/chat/completions`` — OpenAI chat shape: ``messages``
    (stateless role/content list) rendered through the fixed chat
    template (``repro.serving.tokenizer.render_chat``) and tokenized
    with the invertible byte-level codec when the vocabulary fits.
    Extension field ``session`` (positive int) tags the request as a
    turn of a multi-turn conversation: the retain-mode real backend
    anchors the finished turn's KV pages, so the next turn's rendered
    history joins the shared prefix pages (COW, refcounted) instead of
    re-prefilling; ``DELETE /v1/sessions/<id>`` drops the anchor.
  * ``GET /healthz`` — liveness + a scheduler snapshot (strategy, worker
    count, in-flight requests, live queue depth and in-flight slice
    count from the observability gauges, free KV blocks on a paged real
    backend).
  * ``GET /metrics`` — Prometheus text exposition from the
    ``repro.obs`` registry (counters/gauges/histograms; see
    ``docs/observability.md``); falls back to the legacy JSON dump when
    the server was built without a metrics registry.
  * ``GET /metrics.json`` — the legacy one-shot JSON dump (the full
    :class:`RunMetrics` row so far plus the admission counters).
  * ``GET /debug/decisions?rid=&kind=&n=`` — the scheduler decision
    audit ring (admission verdicts with their Eq. 1–2/10–11 inputs,
    ``dp_batch`` compositions, offloader placements with decision-time
    Eq. 11 loads).
  * Admission rejections map to **429** with a ``Retry-After`` header
    derived from the predicted queue delay (converted to wall seconds
    when the server is paced).

Threading model: handler threads never touch the scheduler — every
operation is shipped to the server's event loop with
``asyncio.run_coroutine_threadsafe`` and the core stays single-threaded
(the AsyncSliceServer invariant).  Streaming iterates the handle's
``slices()`` async generator one ``__anext__`` at a time from the handler
thread, so a slow client only blocks its own thread, never the pacer.

There is no tokenizer in this reproduction: string prompts are
pseudo-tokenized (one id per whitespace word, stable hashing into the
vocabulary) and completions are rendered as space-joined token ids.  The
scheduling, admission, streaming, and cancellation paths are the real
thing; only the text codec is a stand-in.
"""
from __future__ import annotations

import asyncio
import json
import math
import threading
import time
import urllib.parse
import zlib
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import asdict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Coroutine, Dict, List, Optional

import numpy as np

from repro.serving.admission import AdmissionRejected, predicted_queue_delay
from repro.serving.aio import AsyncRequestHandle, AsyncSliceServer
from repro.serving.backends import RealBackend, SimBackend
from repro.serving.tokenizer import for_vocab, render_chat

#: default bound on request bodies (1 MiB of JSON is plenty for prompts)
MAX_BODY_BYTES = 1 << 20


class _BadRequest(ValueError):
    pass


def encode_prompt(prompt: Any, vocab_size: int) -> Dict[str, Any]:
    """Normalize the OpenAI ``prompt`` field into submit() kwargs.

    Strings are pseudo-tokenized one id per whitespace word (stable CRC32
    hash into the vocabulary — there is no tokenizer in this repo);
    integer lists are taken as token ids; a bare integer is an input
    length (load-generator extension).  On the sim backend
    (``vocab_size == 0``) only the length matters.
    """
    if isinstance(prompt, bool):
        raise _BadRequest("prompt must be a string, token-id list, or length")
    if isinstance(prompt, str):
        words = prompt.split() or [prompt or "?"]
        if vocab_size > 0:
            ids = [zlib.crc32(w.encode()) % vocab_size for w in words]
            return dict(prompt=np.asarray(ids, np.int32))
        return dict(input_len=len(words))
    if isinstance(prompt, int):
        if prompt <= 0:
            raise _BadRequest(f"prompt length must be positive, got {prompt}")
        if vocab_size > 0:
            # a real backend needs actual token ids, not just a length —
            # synthesize deterministic filler so load generators can still
            # say "a prompt of N tokens"
            return dict(prompt=(np.arange(prompt, dtype=np.int64)
                                * 2654435761 % vocab_size).astype(np.int32))
        return dict(input_len=prompt)
    if isinstance(prompt, list):
        if not prompt or not all(isinstance(t, int) and not isinstance(t, bool)
                                 for t in prompt):
            raise _BadRequest("prompt list must be non-empty token ids")
        if vocab_size > 0:
            return dict(prompt=np.asarray(prompt, np.int32) % vocab_size)
        return dict(input_len=len(prompt))
    raise _BadRequest(f"unsupported prompt type {type(prompt).__name__}")


def _detok(tokens: List[int]) -> str:
    """Debug detokenization: space-joined token ids."""
    return "".join(f" {t}" for t in tokens)


class HTTPFrontend:
    """Serve an :class:`AsyncSliceServer` over HTTP — module docstring."""

    def __init__(self, server: AsyncSliceServer, host: str = "127.0.0.1",
                 port: int = 0, model_name: str = "scls",
                 vocab_size: int = 0, request_timeout: float = 300.0):
        self.aserver = server
        self.model_name = model_name
        self.vocab_size = int(vocab_size)
        self.tokenizer = for_vocab(self.vocab_size)
        self.request_timeout = float(request_timeout)
        self._loop = asyncio.new_event_loop()
        self._loop_thread: Optional[threading.Thread] = None
        self._http_thread: Optional[threading.Thread] = None
        self._httpd = ThreadingHTTPServer((host, port), self._handler_class())
        self.host, self.port = self._httpd.server_address[:2]
        self._started = False

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "HTTPFrontend":
        """Start the scheduler loop thread and the HTTP listener."""
        if self._started:
            return self
        self._started = True
        self._loop_thread = threading.Thread(
            target=self._run_loop, name="slice-http-loop", daemon=True)
        self._loop_thread.start()
        self._call(self._start_pacer())  # pacer lives on the loop thread
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, name="slice-http-listener",
            daemon=True)
        self._http_thread.start()
        return self

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = 60.0) -> None:
        """Stop accepting connections, optionally drain in-flight work,
        and stop the scheduler loop."""
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._loop_thread is not None and self._loop_thread.is_alive():
            try:
                fut = asyncio.run_coroutine_threadsafe(
                    self._shutdown_async(drain), self._loop)
                fut.result(timeout)
            finally:
                self._loop.call_soon_threadsafe(self._loop.stop)
                self._loop_thread.join(timeout=5.0)
        if self._http_thread is not None:
            self._http_thread.join(timeout=5.0)

    def __enter__(self) -> "HTTPFrontend":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.shutdown(drain=exc == (None, None, None))

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def _run_loop(self) -> None:
        asyncio.set_event_loop(self._loop)
        self._loop.run_forever()

    async def _start_pacer(self) -> None:
        self.aserver._ensure_running()

    async def _shutdown_async(self, drain: bool) -> None:
        self.aserver._closed = True  # refuse new submissions first
        if drain:
            while self.aserver.core._events \
                    and self.aserver._pacer_exc is None:
                self.aserver._idle.clear()
                await self.aserver._idle.wait()
        if self.aserver._task is not None:
            self.aserver._task.cancel()
            try:
                await self.aserver._task
            except asyncio.CancelledError:
                pass
            self.aserver._task = None

    def _call(self, coro: Coroutine[Any, Any, Any],
              timeout: Optional[float] = None) -> Any:
        """Run ``coro`` on the scheduler loop from a handler thread."""
        fut = asyncio.run_coroutine_threadsafe(coro, self._loop)
        return fut.result(self.request_timeout if timeout is None else timeout)

    # ------------------------------------------------------------------
    # scheduler-side coroutines (everything that touches the core)
    # ------------------------------------------------------------------
    async def _submit(self, kw: Dict[str, Any]) -> AsyncRequestHandle:
        return self.aserver.submit(**kw)

    async def _snapshot(self) -> Dict[str, Any]:
        core = self.aserver.core
        in_flight = sum(1 for h in self.aserver._handles.values()
                        if not h.finished)
        snap = dict(status="ok", model=self.model_name,
                    strategy=core.s.name, workers=core.n_workers,
                    backend=type(core.backend).__name__,
                    now=core.now, in_flight=in_flight,
                    **self.aserver.admission_stats)
        # live load signals, sourced from the same gauges the registry
        # exports at /metrics (the fleet-router placement inputs); fall
        # back to reading the scheduler directly when obs is off
        if core.obs.ins is not None:
            snap["queue_depth"] = int(core.obs.ins.queue_depth.value())
            snap["in_flight_slices"] = int(core.obs.ins.in_flight.value())
        else:
            snap["queue_depth"] = len(core.pool) + sum(
                len(w.pending) + sum(b.size for b in w.queue)
                for w in core.workers)
            snap["in_flight_slices"] = sum(1 for w in core.workers if w.busy)
        # the full placement-input vector the fleet router's
        # InstanceSnapshot parses (repro.fleet.registry): the Eq. 11
        # per-worker loads and the Eq. 10–11 predicted queue delay the
        # admission controller itself uses, plus memory/session
        # residency for the retention_affinity migration-cost term
        loads = core.offloader.snapshot()
        snap["worker_loads"] = [loads[w] for w in sorted(loads)]
        snap["min_load"] = core.offloader.min_load()
        snap["queue_delay_est"] = predicted_queue_delay(core)
        anchors = getattr(core.backend, "_session_anchor", None)
        snap["n_sessions"] = len(anchors) if anchors is not None else 0
        if core.obs.ins is not None:
            snap["shared_blocks"] = int(core.obs.ins.shared_blocks.value())
        if isinstance(core.backend, RealBackend) \
                and core.backend.allocators is not None:
            snap["free_blocks"] = core.backend.free_blocks()
            snap["kv_retain"] = core.backend.kv_retain
            if core.backend.kv_retain == "request":
                # prefix pages resident across slices (reclaimable on
                # demand — see PagedMemoryEstimator.retained_blocks)
                snap["retained_blocks"] = [a.used_blocks
                                           for a in core.backend.allocators]
        return snap

    async def _metrics(self) -> Dict[str, Any]:
        m = asdict(self.aserver.metrics())
        m.update(self.aserver.admission_stats)
        return m

    async def _metrics_text(self) -> Optional[str]:
        """Prometheus text exposition, or None when the server was built
        without a metrics registry (legacy JSON keeps serving /metrics)."""
        registry = self.aserver.core.obs.registry
        return None if registry is None else registry.render()

    async def _decisions(self, rid: Optional[int], kind: Optional[str],
                         limit: Optional[int]) -> Dict[str, Any]:
        audit = self.aserver.core.obs.audit
        if audit is None:
            return dict(enabled=False, events=[])
        events = audit.query(rid=rid, kind=kind, limit=limit)
        return dict(enabled=True, n_recorded=audit.n_recorded,
                    capacity=audit.capacity, events=events)

    # ------------------------------------------------------------------
    # request parsing / response shaping
    # ------------------------------------------------------------------
    def _gen_opts(self, body: Dict[str, Any]) -> Dict[str, Any]:
        """The generation/admission knobs shared by both POST endpoints."""
        kw: Dict[str, Any] = {}
        max_tokens = body.get("max_tokens", 16)
        if not isinstance(max_tokens, int) or isinstance(max_tokens, bool) \
                or max_tokens <= 0:
            raise _BadRequest(f"max_tokens must be a positive integer, "
                              f"got {max_tokens!r}")
        kw["max_gen"] = max_tokens
        slo_ms = body.get("slo_ms")
        if slo_ms is not None:
            if not isinstance(slo_ms, (int, float)) or slo_ms <= 0:
                raise _BadRequest(f"slo_ms must be a positive number, "
                                  f"got {slo_ms!r}")
            kw["slo_ms"] = float(slo_ms)
        kw["allow_degrade"] = bool(body.get("allow_degrade", False))
        return kw

    def _parse_completion(self, body: Dict[str, Any]) -> Dict[str, Any]:
        if "prompt" not in body:
            raise _BadRequest("missing required field 'prompt'")
        kw = encode_prompt(body["prompt"], self.vocab_size)
        kw.update(self._gen_opts(body))
        return kw

    def _parse_chat(self, body: Dict[str, Any]) -> Dict[str, Any]:
        """``/v1/chat/completions``: render the stateless OpenAI message
        list through the fixed chat template and tokenize.  The
        ``session`` extension field tags the request so the retain-mode
        real backend anchors its pages — the next turn of the same
        session (whose rendered prompt extends this one) then joins the
        shared prefix pages instead of re-prefilling the history."""
        messages = body.get("messages")
        if not isinstance(messages, list) or not messages:
            raise _BadRequest("messages must be a non-empty list")
        try:
            text = render_chat(messages)
        except ValueError as e:
            raise _BadRequest(str(e)) from None
        if self.tokenizer is not None:
            kw: Dict[str, Any] = dict(
                prompt=np.asarray(self.tokenizer.encode(text), np.int32))
        else:  # length-only sim backend
            kw = dict(input_len=max(1, len(text.split())))
        kw.update(self._gen_opts(body))
        session = body.get("session")
        if session is not None:
            if not isinstance(session, int) or isinstance(session, bool) \
                    or session <= 0:
                raise _BadRequest(f"session must be a positive integer, "
                                  f"got {session!r}")
            kw["session_id"] = session
        return kw

    def _decode_text(self, tokens: List[int]) -> str:
        """Completion text: real detokenization when the codec round-trips,
        else the debug space-joined ids."""
        if self.tokenizer is not None and self.tokenizer.invertible:
            return self.tokenizer.decode(tokens)
        return _detok(tokens)

    def _completion_obj(self, handle: AsyncRequestHandle, text: str,
                        finish_reason: Optional[str],
                        usage: bool = False) -> Dict[str, Any]:
        obj: Dict[str, Any] = dict(
            id=f"cmpl-{handle.rid}", object="text_completion",
            created=int(time.time()), model=self.model_name,
            choices=[dict(index=0, text=text, logprobs=None,
                          finish_reason=finish_reason)])
        if usage:
            req = handle.request
            obj["usage"] = dict(prompt_tokens=req.input_len,
                                completion_tokens=req.generated,
                                total_tokens=req.input_len + req.generated)
        return obj

    def _chat_obj(self, handle: AsyncRequestHandle, content: str,
                  finish_reason: Optional[str], usage: bool = False,
                  chunk: bool = False) -> Dict[str, Any]:
        if chunk:
            delta = dict(role="assistant", content=content) if content else {}
            choice = dict(index=0, delta=delta, finish_reason=finish_reason)
            obj_type = "chat.completion.chunk"
        else:
            choice = dict(index=0,
                          message=dict(role="assistant", content=content),
                          finish_reason=finish_reason)
            obj_type = "chat.completion"
        obj: Dict[str, Any] = dict(
            id=f"chatcmpl-{handle.rid}", object=obj_type,
            created=int(time.time()), model=self.model_name,
            choices=[choice])
        if usage:
            req = handle.request
            obj["usage"] = dict(prompt_tokens=req.input_len,
                                completion_tokens=req.generated,
                                total_tokens=req.input_len + req.generated)
        if handle.request.session_id is not None:
            obj["session"] = handle.request.session_id
        return obj

    def _finish_reason(self, handle: AsyncRequestHandle) -> str:
        if handle.cancelled:
            return "cancelled"
        req = handle.request
        if req.gen_len is None and req.generated < req.max_gen:
            return "stop"    # the model's own EOS ended the stream
        return "length"

    def _retry_after_s(self, exc: AdmissionRejected) -> float:
        ra = exc.decision.retry_after or 1.0
        scale = self.aserver._time_scale
        if scale is not None:
            # core seconds -> wall seconds, the same mapping the pacer
            # applies to submissions; a paced run legitimately suggests
            # sub-second wall backoffs, so don't floor them to 1 —
            # clamp to 1 ms and keep millisecond resolution instead
            return round(max(ra / scale, 1e-3), 3)
        if isinstance(self.aserver.core.backend, SimBackend):
            # unpaced sim: virtual backlog clears in ~zero wall time, so
            # a virtual-seconds header would over-throttle clients
            ra = 1.0
        return max(1, math.ceil(ra))

    # ------------------------------------------------------------------
    # the handler class (closure over this frontend)
    # ------------------------------------------------------------------
    def _handler_class(self) -> type:
        front = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"
            server_version = "SliceServer/1.0"

            def log_message(self, fmt, *args):  # noqa: D102 — quiet CI logs
                pass

            # -- plumbing ----------------------------------------------
            def _json(self, code: int, obj: Dict[str, Any],
                      headers: Optional[Dict[str, str]] = None) -> None:
                payload = json.dumps(obj).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(payload)))
                for k, v in (headers or {}).items():
                    self.send_header(k, v)
                self.end_headers()
                self.wfile.write(payload)

            def _error(self, code: int, message: str, etype: str,
                       headers: Optional[Dict[str, str]] = None) -> None:
                self._json(code, {"error": {"message": message, "type": etype,
                                            "code": code}}, headers)

            def _read_body(self) -> Dict[str, Any]:
                n = int(self.headers.get("Content-Length") or 0)
                if n <= 0:
                    raise _BadRequest("empty request body")
                if n > MAX_BODY_BYTES:
                    raise _BadRequest(f"request body exceeds "
                                      f"{MAX_BODY_BYTES} bytes")
                try:
                    body = json.loads(self.rfile.read(n))
                except json.JSONDecodeError as e:
                    raise _BadRequest(f"invalid JSON: {e}") from None
                if not isinstance(body, dict):
                    raise _BadRequest("request body must be a JSON object")
                return body

            def _text(self, code: int, body: str, content_type: str) -> None:
                payload = body.encode()
                self.send_response(code)
                self.send_header("Content-Type", content_type)
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)

            def _query_params(self) -> Dict[str, str]:
                parts = self.path.split("?", 1)
                if len(parts) == 1:
                    return {}
                return {k: v[-1] for k, v in
                        urllib.parse.parse_qs(parts[1]).items()}

            # -- routes -------------------------------------------------
            def do_GET(self) -> None:  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                if path == "/healthz":
                    self._json(200, front._call(front._snapshot()))
                elif path == "/metrics":
                    text = front._call(front._metrics_text())
                    if text is None:  # no registry: legacy JSON dump
                        self._json(200, front._call(front._metrics()))
                    else:
                        self._text(200, text,
                                   "text/plain; version=0.0.4; charset=utf-8")
                elif path == "/metrics.json":
                    self._json(200, front._call(front._metrics()))
                elif path == "/debug/decisions":
                    q = self._query_params()
                    try:
                        rid = int(q["rid"]) if "rid" in q else None
                        limit = int(q["n"]) if "n" in q else None
                    except ValueError:
                        self._error(400, "rid and n must be integers",
                                    "invalid_request_error")
                        return
                    self._json(200, front._call(
                        front._decisions(rid, q.get("kind"), limit)))
                elif path == "/v1/models":
                    self._json(200, {"object": "list", "data": [
                        {"id": front.model_name, "object": "model",
                         "owned_by": "repro.serving"}]})
                else:
                    self._error(404, f"no route {path}", "invalid_request_error")

            def do_POST(self) -> None:  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                if path not in ("/v1/completions", "/v1/chat/completions"):
                    self._error(404, f"no route {path}", "invalid_request_error")
                    return
                chat = path == "/v1/chat/completions"
                try:
                    body = self._read_body()
                    kw = (front._parse_chat(body) if chat
                          else front._parse_completion(body))
                except _BadRequest as e:
                    self._error(400, str(e), "invalid_request_error")
                    return
                stream = bool(body.get("stream", False))
                try:
                    handle = front._call(front._submit(kw))
                except AdmissionRejected as e:
                    self._error(
                        429, str(e), "rate_limit_exceeded",
                        {"Retry-After": str(front._retry_after_s(e))})
                    return
                except RuntimeError as e:  # server closed / draining
                    self._error(503, str(e), "server_error",
                                {"Retry-After": "1"})
                    return
                if stream:
                    self._stream(handle, chat)
                else:
                    self._complete(handle, chat)

            def do_DELETE(self) -> None:  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                if not path.startswith("/v1/sessions/"):
                    self._error(404, f"no route {path}", "invalid_request_error")
                    return
                try:
                    sid = int(path[len("/v1/sessions/"):])
                except ValueError:
                    self._error(400, "session id must be an integer",
                                "invalid_request_error")
                    return
                front._call(front._release_session(sid))
                self._json(200, {"object": "session", "id": sid,
                                 "released": True})

            # -- completion bodies -------------------------------------
            def _body_obj(self, chat: bool, handle: AsyncRequestHandle,
                          text: str, finish_reason: Optional[str],
                          usage: bool = False,
                          chunk: bool = False) -> Dict[str, Any]:
                if chat:
                    return front._chat_obj(handle, text, finish_reason,
                                           usage=usage, chunk=chunk)
                return front._completion_obj(handle, text, finish_reason,
                                             usage=usage)

            def _complete(self, handle: AsyncRequestHandle,
                          chat: bool = False) -> None:
                try:
                    front._call(handle.result())
                except FuturesTimeout:
                    # stop spending slices on a response nobody will get
                    front._call(front._cancel(handle))
                    self._error(504, "request timed out", "server_error")
                    return
                text = (front._decode_text(handle.output_tokens) if chat
                        else _detok(handle.output_tokens))
                self._json(200, self._body_obj(
                    chat, handle, text, front._finish_reason(handle),
                    usage=True))

            def _stream(self, handle: AsyncRequestHandle,
                        chat: bool = False) -> None:
                """SSE: one ``data:`` chunk per completed slice."""
                self.send_response(200)
                self.send_header("Content-Type", "text/event-stream")
                self.send_header("Cache-Control", "no-cache")
                self.send_header("Connection", "close")
                self.end_headers()
                agen = handle.slices()
                try:
                    while True:
                        try:
                            chunk = front._call(agen.__anext__())
                        except StopAsyncIteration:
                            break
                        text = (front._decode_text(chunk) if chat
                                else _detok(chunk))
                        obj = self._body_obj(chat, handle, text, None,
                                             chunk=True)
                        self.wfile.write(b"data: " + json.dumps(obj).encode()
                                         + b"\n\n")
                        self.wfile.flush()
                    final = self._body_obj(
                        chat, handle, "", front._finish_reason(handle),
                        usage=True, chunk=True)
                    self.wfile.write(b"data: " + json.dumps(final).encode()
                                     + b"\n\n")
                    self.wfile.write(b"data: [DONE]\n\n")
                    self.wfile.flush()
                except (BrokenPipeError, ConnectionResetError,
                        FuturesTimeout):
                    # client went away (or stalled past the timeout)
                    # mid-stream: cancel so the scheduler stops spending
                    # slices on it (next boundary frees the page envelope)
                    front._call(front._cancel(handle))

        return Handler

    async def _release_session(self, session_id: int) -> None:
        self.aserver.release_session(session_id)

    async def _cancel(self, handle: AsyncRequestHandle) -> bool:
        return handle.cancel()
