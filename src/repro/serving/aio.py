"""Async serving front end: N concurrent clients over one SchedulerCore.

The PR 3 ``SliceServer`` is a *synchronous reactor*: whichever caller
invokes ``tokens()`` / ``result()`` drives the shared event queue, so only
one client can use it at a time.  ``AsyncSliceServer`` turns the same
:class:`~repro.serving.core.SchedulerCore` into a concurrent service: a
single background task (the *pacer*) steps the core, and any number of
client coroutines submit, stream, cancel, and await results::

    server = ServingConfig(strategy="scls", workers=4).build_sim().aio

    async def client(i):
        h = server.submit(input_len=64, gen_len=100, slo_ms=30_000)
        async for tok in h.tokens():     # wakes at slice boundaries
            ...
        return await h.result()

    await asyncio.gather(*(client(i) for i in range(16)))

Concurrency model — one event loop, **no locks in the core**: ``submit``
and ``cancel`` are plain synchronous methods that mutate the core
in-line, the pacer is the only task that calls ``core.step()``, and
client coroutines never touch the core — they wait on per-handle events
pulsed by the core's progress observers.  Every interleaving is therefore
a sequence of atomic core transitions, exactly as in the offline runs.

Wall-clock pacing: with ``time_scale=k`` (sim backend only) the pacer
sleeps so that virtual second ``t`` occurs at wall second ``t / k`` after
start, and submissions map the wall clock back to virtual arrival times —
``k = 1`` serves the simulated cluster in real time (what the HTTP front
end uses), large ``k`` compresses it.  With ``time_scale=None`` (default)
events are processed as fast as possible; on the real backend the engines
themselves consume wall time inside ``step()``, so no pacing is applied.

SLO-aware admission (``repro.serving.admission``) runs inside ``submit``:
a request whose predicted completion violates its ``slo_ms``/``deadline``
raises :class:`~repro.serving.admission.AdmissionRejected` *before* any
page reservation or prefill work.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Any, AsyncIterator, List, Optional, Sequence

import numpy as np

from repro.cluster.metrics import RunMetrics
from repro.core.request import Request
from repro.core.schedulers import StrategyConfig
from repro.serving.admission import (AdmissionController, AdmissionDecision,
                                     AdmissionRejected)
from repro.serving.backends import SimBackend
from repro.serving.core import SchedulerCore

#: server-assigned request ids live in their own namespace so interactive
#: ``submit`` calls never collide with trace rids (0..n) fed to ``replay``
_SERVER_RID_BASE = 1 << 32


class RequestView:
    """Read-only view of one submitted request (shared by the sync and
    async handles — all state lives in the core/request, never here)."""

    def __init__(self, server: Any, request: Request):
        self._server = server
        self.request = request

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def finished(self) -> bool:
        """Terminal (completed or cancelled)."""
        return self._server.core.is_finalized(self.rid)

    @property
    def done(self) -> bool:
        """Completed successfully."""
        return self.finished and self.request.done

    @property
    def cancelled(self) -> bool:
        return self.request.cancelled

    def _tokens_so_far(self) -> Sequence[int]:
        toks = self._server.core.token_log.get(self.rid)
        if toks is not None:  # real backend, mid-flight
            return toks
        if self.finished and self.request.output_tokens is not None:
            return self.request.output_tokens  # real backend, terminal
        # sim backend: token ids are by definition the generation indices
        return range(self.request.generated)

    @property
    def output_tokens(self) -> List[int]:
        """Tokens produced so far (all of them once terminal)."""
        return list(self._tokens_so_far())


class AsyncRequestHandle(RequestView):
    """Awaitable view of one request on an :class:`AsyncSliceServer`.

    Slice boundaries are recorded as they happen (``_marks``), so
    ``slices()`` reproduces the true per-slice chunking even when the
    consumer polls slower than the pacer produces — the property the SSE
    streaming endpoint relies on.
    """

    def __init__(self, server: "AsyncSliceServer", request: Request):
        super().__init__(server, request)
        self._event = asyncio.Event()
        self._marks: List[int] = []  # cumulative token count per slice

    # -- called by the server's core observer (inside the pacer step) ----
    def _pulse(self, kind: str) -> None:
        if kind in ("slice", "final"):
            n = len(self._tokens_so_far())
            if n > (self._marks[-1] if self._marks else 0):
                self._marks.append(n)
        self._event.set()

    async def _wait(self) -> None:
        # progress check FIRST: a woken waiter must observe a pacer
        # failure before _ensure_running clears it for the restart
        self._server._check_progress(self.request)
        self._server._ensure_running()
        self._event.clear()
        await self._event.wait()

    # -- client API ------------------------------------------------------
    async def result(self) -> Request:
        """Wait until this request is terminal; returns the finalized
        :class:`Request` (cancelled requests return too — check ``done``)."""
        self._server._ensure_running()
        while not self.finished:
            await self._wait()
        return self.request

    async def tokens(self) -> AsyncIterator[int]:
        """Stream this request's tokens; wakes at slice boundaries."""
        self._server._ensure_running()
        cursor = 0
        while True:
            toks = self._tokens_so_far()
            while cursor < len(toks):
                yield toks[cursor]
                cursor += 1
            if self.finished:
                # the pacer may have finalized (and grown the stream)
                # while a consumer awaited between yields above — the
                # snapshot in `toks` is stale, so re-read before ending
                toks = self._tokens_so_far()
                while cursor < len(toks):
                    yield toks[cursor]
                    cursor += 1
                return
            await self._wait()

    async def slices(self) -> AsyncIterator[List[int]]:
        """Stream token chunks, one per completed slice (the scheduling
        atom) — the granularity the SSE endpoint emits."""
        self._server._ensure_running()
        cursor, mi = 0, 0
        while True:
            while mi < len(self._marks):
                mark = self._marks[mi]
                mi += 1
                if mark > cursor:
                    yield list(self._tokens_so_far()[cursor:mark])
                    cursor = mark
            if self.finished:
                toks = self._tokens_so_far()
                if len(toks) > cursor:
                    yield list(toks[cursor:])
                return
            await self._wait()

    def cancel(self) -> bool:
        """Cancel this request — queued: immediate; in flight: at the next
        slice/lease boundary (page envelope freed there)."""
        return self._server.cancel(self.rid)


class AsyncSliceServer:
    """Concurrent submit / stream / cancel front end — module docstring."""

    def __init__(self, core: SchedulerCore,
                 admission: Optional[AdmissionController] = None,
                 default_slo_ms: Optional[float] = None,
                 time_scale: Optional[float] = None):
        if time_scale is not None:
            if time_scale <= 0:
                raise ValueError(f"time_scale must be positive, got {time_scale}")
            if not isinstance(core.backend, SimBackend):
                raise ValueError(
                    "wall-clock pacing maps virtual to wall time, which only "
                    "the sim backend has; the real backend's engines consume "
                    "wall time inside step() already (use time_scale=None)")
        self.core = core
        self.admission = admission if admission is not None \
            else AdmissionController()
        self.default_slo_ms = default_slo_ms
        self._time_scale = time_scale
        self._next_rid = itertools.count(_SERVER_RID_BASE)
        self._next_sid = itertools.count(1)
        self._handles: dict[int, AsyncRequestHandle] = {}
        self._closed = False
        # pacer machinery (bound lazily to the first running loop we see)
        self._task: Optional[asyncio.Task] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._wall_t0: Optional[float] = None
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._pacer_exc: Optional[BaseException] = None
        # admission accounting (mirrors core.n_rejected for convenience)
        self.n_submitted = 0
        self.n_degraded = 0
        core.add_observer(self._on_core_event)

    # ------------------------------------------------------------------
    @property
    def strategy(self) -> StrategyConfig:
        return self.core.s

    @property
    def now(self) -> float:
        return self.core.now

    @property
    def n_rejected(self) -> int:
        return self.core.n_rejected

    @property
    def admission_stats(self) -> dict:
        return dict(n_submitted=self.n_submitted,
                    n_rejected=self.core.n_rejected,
                    n_degraded=self.n_degraded,
                    reject_reasons=dict(self.core.reject_reasons))

    # ------------------------------------------------------------------
    # submission (synchronous on purpose: one loop, no interleaving
    # between admission check and core mutation)
    # ------------------------------------------------------------------
    def submit(self, prompt: Optional[np.ndarray] = None, *,
               input_len: Optional[int] = None,
               gen_len: Optional[int] = None,
               max_gen: int = 1024,
               arrival: Optional[float] = None,
               slo_ms: Optional[float] = None,
               deadline: Optional[float] = None,
               allow_degrade: bool = False,
               session_id: Optional[int] = None) -> AsyncRequestHandle:
        """Admit one request; returns a handle immediately.

        ``slo_ms`` sets ``deadline = arrival + slo_ms / 1000`` in core
        time (virtual seconds on the sim backend — wall seconds when paced
        at ``time_scale=1``).  A request whose predicted completion
        violates the deadline raises
        :class:`~repro.serving.admission.AdmissionRejected` *before* any
        page reservation or prefill; with ``allow_degrade=True`` it is
        instead admitted with the longest generation budget that still
        meets the deadline (when one exists).
        """
        if self._closed:
            raise RuntimeError("server is closed")
        if prompt is None and input_len is None:
            raise ValueError("need a prompt or an input_len")
        if prompt is not None:
            prompt = np.asarray(prompt, np.int32)
            if input_len is None:
                input_len = int(prompt.shape[0])
        input_len = int(input_len)
        arrival_t = self._arrival_now() if arrival is None else float(arrival)
        if slo_ms is None:
            slo_ms = self.default_slo_ms
        deadline_t = deadline if deadline is not None else (
            arrival_t + slo_ms / 1e3 if slo_ms is not None else None)
        declared = (min(int(gen_len), int(max_gen))
                    if gen_len is not None else int(max_gen))

        decision = self.admission.decide(
            self.core, input_len=input_len, declared_gen=declared,
            arrival=arrival_t, deadline=deadline_t,
            allow_degrade=allow_degrade)
        if not decision.accept:
            self.core.n_rejected += 1
            code = decision.reason_code or "other"
            self.core.reject_reasons[code] = \
                self.core.reject_reasons.get(code, 0) + 1
            if self.core.obs.enabled:
                # rejects carry rid=None — none was ever assigned
                self.core.obs.on_admission(
                    self.core, decision, input_len=input_len,
                    declared_gen=declared, deadline=deadline_t)
            raise AdmissionRejected(decision)
        if decision.action == "degrade":
            self.n_degraded += 1
            max_gen = decision.max_gen
            if gen_len is not None:
                gen_len = min(int(gen_len), max_gen)

        rid = next(self._next_rid)
        while rid in self.core._by_rid:  # replay() may have taken ids
            rid = next(self._next_rid)
        if self.core.obs.enabled:
            self.core.obs.on_admission(
                self.core, decision, input_len=input_len,
                declared_gen=declared, deadline=deadline_t, rid=rid)
        req = Request(rid=rid, arrival=arrival_t, input_len=input_len,
                      gen_len=None if gen_len is None else int(gen_len),
                      max_gen=int(max_gen), prompt=prompt,
                      deadline=deadline_t, session_id=session_id)
        self.core.submit(req)
        self.n_submitted += 1
        h = AsyncRequestHandle(self, req)
        self._handles[rid] = h
        self._kick()
        return h

    def replay(self, requests: Sequence[Request]) -> List[AsyncRequestHandle]:
        """Submit pre-built trace requests (mutated in place, like the
        legacy ``run()`` path).  Trace replay bypasses admission — it
        reproduces recorded workloads, deadlines and all."""
        if self._closed:
            raise RuntimeError("server is closed")
        handles = []
        for r in requests:
            self.core.submit(r)
            self.n_submitted += 1
            h = AsyncRequestHandle(self, r)
            self._handles[r.rid] = h
            handles.append(h)
        self._kick()
        return handles

    def cancel(self, rid: int) -> bool:
        out = self.core.cancel(rid)
        self._kick()
        return out

    # ------------------------------------------------------------------
    # multi-turn sessions
    # ------------------------------------------------------------------
    def session(self, session_id: Optional[int] = None, *,
                max_gen: int = 1024,
                slo_ms: Optional[float] = None) -> "Session":
        """Open a multi-turn :class:`Session`.  Each turn is one ordinary
        request carrying the whole conversation so far as its prompt; on
        the real retain-mode backend the previous turn's KV pages are
        anchored per session, so the next turn's shared prefix becomes a
        refcounted page-table join instead of a re-prefill."""
        if session_id is None:
            session_id = next(self._next_sid)
        return Session(self, int(session_id), max_gen=max_gen, slo_ms=slo_ms)

    def release_session(self, session_id: int) -> None:
        """Drop the backend's page anchor for ``session_id`` (no-op on
        backends without retention)."""
        self.core.backend.release_session(int(session_id))

    def check_admission(self, *, input_len: int, gen_len: Optional[int] = None,
                        max_gen: int = 1024,
                        slo_ms: Optional[float] = None) -> AdmissionDecision:
        """Dry-run the admission decision for a prospective request
        without submitting (used by load shedders and tests)."""
        arrival_t = self._arrival_now()
        declared = (min(int(gen_len), int(max_gen))
                    if gen_len is not None else int(max_gen))
        deadline_t = arrival_t + slo_ms / 1e3 if slo_ms is not None else None
        return self.admission.decide(self.core, input_len=int(input_len),
                                     declared_gen=declared, arrival=arrival_t,
                                     deadline=deadline_t)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def drain(self, duration: Optional[float] = None) -> RunMetrics:
        """Wait until every event (including paced future arrivals) has
        been processed; returns the run metrics so far."""
        if self._pacer_exc is not None:  # before _ensure_running clears it
            raise self._pacer_exc
        self._ensure_running()
        while self.core._events:
            if self._pacer_exc is not None:
                raise self._pacer_exc
            self._idle.clear()
            await self._idle.wait()
        if self._pacer_exc is not None:
            raise self._pacer_exc
        return self.core.metrics(duration)

    async def close(self, duration: Optional[float] = None) -> RunMetrics:
        """Drain, refuse further submissions, and stop the pacer task."""
        m = await self.drain(duration)
        self._closed = True
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        return m

    def metrics(self, duration: Optional[float] = None) -> RunMetrics:
        return self.core.metrics(duration)

    async def __aenter__(self) -> "AsyncSliceServer":
        self._ensure_running()
        return self

    async def __aexit__(self, *exc: object) -> None:
        if exc == (None, None, None):
            await self.close()
        elif self._task is not None:  # on error, don't mask it by draining
            self._task.cancel()

    # ------------------------------------------------------------------
    # pacer internals
    # ------------------------------------------------------------------
    def _on_core_event(self, kind: str, req: Request) -> None:
        h = self._handles.get(req.rid)
        if h is not None:
            h._pulse(kind)
            if kind == "final":
                # terminal: the handle works standalone from here (state
                # lives on the request/core), so drop our reference — a
                # serve-forever deployment must not accumulate one entry
                # per request ever served
                del self._handles[req.rid]

    def _arrival_now(self) -> float:
        """Current time for a new submission: the wall clock mapped back
        to virtual time when paced, else the core's clock."""
        if self._time_scale is not None and self._wall_t0 is not None \
                and self._loop is not None:
            mapped = (self._loop.time() - self._wall_t0) * self._time_scale
            return max(self.core.now, mapped)
        return self.core.now

    def _kick(self) -> None:
        """Wake the pacer after a submission/cancellation (no-op when no
        loop is running — the sync adapter drives the core itself)."""
        self._idle.clear()
        self._wake.set()
        self._ensure_running()

    def _ensure_running(self) -> None:
        try:
            loop = asyncio.get_running_loop()
        except RuntimeError:
            return  # sync context: SliceServer steps the core directly
        if self._task is not None and self._task.done():
            if not self._task.cancelled():
                # retrieve the exception (it was already delivered to every
                # waiter via _pacer_exc at crash time) so asyncio doesn't
                # log "exception was never retrieved"
                self._task.exception()
            self._task = None
        if self._task is None and not self._closed:
            self._loop = loop
            if self._wall_t0 is None:
                self._wall_t0 = loop.time()
            # a fresh pacer starts clean: the old failure was surfaced to
            # its contemporaries, and a sticky exception would poison
            # every future (healthy) request forever
            self._pacer_exc = None
            self._task = loop.create_task(self._pace(),
                                          name="AsyncSliceServer.pacer")

    def _check_progress(self, req: Request) -> None:
        if self._pacer_exc is not None:
            raise self._pacer_exc
        if not self.core._events and not self.core.is_finalized(req.rid):
            raise RuntimeError(
                f"request {req.rid} cannot make progress: the event "
                f"queue is empty but it never finalized")

    async def _pace(self) -> None:
        """THE stepping task: the only caller of ``core.step()`` while the
        server is live, so core transitions never interleave."""
        core = self.core
        while True:
            if not core._events:
                self._idle.set()
                # wake any waiter stuck on a request that can no longer
                # progress (its next _wait() raises, same contract as the
                # sync reactor)
                for h in self._handles.values():
                    if not h.finished:
                        h._event.set()
                self._wake.clear()
                await self._wake.wait()
                continue
            if self._time_scale is not None:
                t_next = core._events[0][0]
                delay = (self._wall_t0 + t_next / self._time_scale
                         - self._loop.time())
                if delay > 0:
                    self._wake.clear()
                    try:  # a submit/cancel may preempt with earlier work
                        await asyncio.wait_for(self._wake.wait(), delay)
                    except asyncio.TimeoutError:
                        pass
                    continue  # re-evaluate the earliest event either way
            try:
                core.step()
            except BaseException as e:
                # a failed step would otherwise strand every waiter on an
                # event that never fires: record it, wake everyone (their
                # next _wait()/drain() re-raises), and die loudly
                self._pacer_exc = e
                for h in self._handles.values():
                    h._event.set()
                self._idle.set()
                raise
            await asyncio.sleep(0)  # let clients run between transitions


class Session:
    """One multi-turn conversation over an :class:`AsyncSliceServer`.

    A session is a thin client-side convention plus a server-side page
    anchor: every turn is an ordinary request whose prompt is the whole
    conversation so far (history + new user tokens), tagged with this
    session's id.  Schedulers never see sessions — only the real
    retain-mode backend reads the tag, to keep the finished turn's prefix
    pages resident so the next turn's history prefix becomes a refcounted
    page-table join (``PageAllocator.share``) instead of a re-prefill.
    On the sim backend a session still composes correctly (turn prompts
    grow by the accumulated length); there is just no KV to share.

    ``submit_turn`` may be called while the previous turn is still in
    flight — even mid-slice — in which case it awaits that turn's result
    first, so history is always complete before the next prompt is built.

    Close (or ``async with``) cancels any in-flight turn and drops the
    backend anchor, returning the session's pages to the free pool.
    """

    def __init__(self, server: AsyncSliceServer, session_id: int, *,
                 max_gen: int = 1024, slo_ms: Optional[float] = None):
        self._server = server
        self.session_id = int(session_id)
        self.default_max_gen = int(max_gen)
        self.default_slo_ms = slo_ms
        self._history_tokens: Optional[np.ndarray] = None  # real backend
        self._history_len = 0
        self._last: Optional[AsyncRequestHandle] = None
        self._closed = False
        self.n_turns = 0

    # ------------------------------------------------------------------
    @property
    def history_len(self) -> int:
        """Tokens of conversation context the *next* turn will carry
        (completed turns only — an in-flight turn is not yet absorbed)."""
        return self._history_len

    @property
    def history_tokens(self) -> Optional[List[int]]:
        """Token-level history (real backend; ``None`` in length-only
        sim sessions that never saw a prompt array)."""
        return None if self._history_tokens is None \
            else list(self._history_tokens)

    @property
    def last(self) -> Optional[AsyncRequestHandle]:
        """Handle of the most recently submitted turn, if any."""
        return self._last

    # ------------------------------------------------------------------
    def _absorb_last(self) -> None:
        """Fold the finished previous turn into history.  Cancelled turns
        are dropped (their pages were freed; history stays pre-turn)."""
        h = self._last
        self._last = None
        if h is None or not h.request.done or h.request.cancelled:
            return
        if h.request.prompt is not None:
            self._history_tokens = np.concatenate(
                [np.asarray(h.request.prompt, np.int32),
                 np.asarray(h.output_tokens, np.int32)])
            self._history_len = int(self._history_tokens.shape[0])
        else:
            self._history_len = h.request.input_len + h.request.generated

    async def submit_turn(self, prompt: Optional[np.ndarray] = None, *,
                          input_len: Optional[int] = None,
                          gen_len: Optional[int] = None,
                          max_gen: Optional[int] = None,
                          slo_ms: Optional[float] = None,
                          allow_degrade: bool = False
                          ) -> AsyncRequestHandle:
        """Submit the next turn: ``prompt`` (real) or ``input_len`` (sim)
        is the *new* user message only — the accumulated history is
        prepended here.  Raises
        :class:`~repro.serving.admission.AdmissionRejected` like
        ``submit`` (the session survives; retry or close)."""
        if self._closed:
            raise RuntimeError("session is closed")
        if prompt is None and input_len is None:
            raise ValueError("need a prompt or an input_len")
        if self._last is not None and not self._last.finished:
            await self._last.result()
        self._absorb_last()
        total_len = None
        if prompt is not None:
            prompt = np.asarray(prompt, np.int32)
            if self._history_tokens is not None:
                prompt = np.concatenate([self._history_tokens, prompt])
        else:
            total_len = self._history_len + int(input_len)
        h = self._server.submit(
            prompt, input_len=total_len, gen_len=gen_len,
            max_gen=self.default_max_gen if max_gen is None else int(max_gen),
            slo_ms=self.default_slo_ms if slo_ms is None else slo_ms,
            allow_degrade=allow_degrade, session_id=self.session_id)
        self._last = h
        self.n_turns += 1
        return h

    async def close(self) -> None:
        """Cancel any in-flight turn and release the backend anchor."""
        if self._closed:
            return
        self._closed = True
        h = self._last
        if h is not None and not h.finished:
            h.cancel()
            await h.result()
        self._server.release_session(self.session_id)

    async def __aenter__(self) -> "Session":
        return self

    async def __aexit__(self, *exc: object) -> None:
        await self.close()
