"""SLO-aware admission control for the online serving API.

The paper's core claim (§3.2, Eq. 1–4) is that slice-level scheduling
gives a *precise range of serving time and memory usage* for a batch.
This module is where that precision becomes operational: before a request
costs any prefill work or page reservation, the controller predicts when
it would complete — queue delay from the Eq. 10–11 worker loads plus the
Eq. 1–4 slice time estimates over a calibrated generation-length cap
(``repro.predict``) — and compares the prediction against the request's
deadline.  A request whose predicted completion violates its SLO is
rejected (HTTP 429 upstream) or, when the caller opts in, *degraded* to
the longest ``max_gen`` that still meets the deadline.

Three decision shapes (the ``AdmissionDecision`` constructors):

  * ``AdmissionDecision.accepted(...)``   — proceed, prediction attached;
  * ``AdmissionDecision.rejected(reason)``— shed now, nothing reserved;
  * ``AdmissionDecision.degraded(max_gen)``— admit with a shorter budget.

Requests without a deadline are always admitted (best-effort traffic is
never shed), so attaching a controller to a server changes nothing for
existing SLO-less callers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.serving.core import SchedulerCore


class AdmissionRejected(RuntimeError):
    """Raised by ``submit`` when the admission controller sheds a request
    (maps to HTTP 429 + ``Retry-After`` in ``repro.serving.http``)."""

    def __init__(self, decision: "AdmissionDecision"):
        super().__init__(decision.reason or "request rejected by admission")
        self.decision = decision


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of one admission check.

    ``action`` is one of ``"accept"`` / ``"reject"`` / ``"degrade"``;
    ``predicted_completion`` is the controller's estimate of the absolute
    (core-time) completion instant, ``retry_after`` a suggested backoff in
    core seconds for rejected requests, ``max_gen`` the degraded
    generation budget for ``"degrade"`` decisions.

    The decision *inputs* ride along for the observability layer
    (``repro.obs`` decision audit / per-reason reject metrics):
    ``reason_code`` is a stable machine key (``"memory"`` — the Eq. 5–9
    bound admits no batch of one; ``"deadline"`` — the prediction misses
    the SLO), ``queue_delay`` the Eq. 10–11 predicted queueing delay,
    ``service_est`` the Eq. 1–4 service-time estimate at ``gen_cap``
    generated tokens.  All 0/None for accept-all and best-effort paths
    where they were never computed.
    """

    action: str
    reason: Optional[str] = None
    predicted_completion: float = 0.0
    retry_after: Optional[float] = None
    max_gen: Optional[int] = None
    reason_code: Optional[str] = None
    queue_delay: float = 0.0
    service_est: float = 0.0
    gen_cap: Optional[int] = None

    @property
    def accept(self) -> bool:
        """True when the request may enter the scheduler (possibly with a
        degraded budget)."""
        return self.action in ("accept", "degrade")

    # -- constructors ---------------------------------------------------
    @classmethod
    def accepted(cls, predicted_completion: float = 0.0,
                 **inputs: Any) -> "AdmissionDecision":
        return cls("accept", predicted_completion=predicted_completion,
                   **inputs)

    @classmethod
    def rejected(cls, reason: str, predicted_completion: float = 0.0,
                 retry_after: Optional[float] = None,
                 **inputs: Any) -> "AdmissionDecision":
        return cls("reject", reason=reason,
                   predicted_completion=predicted_completion,
                   retry_after=retry_after, **inputs)

    @classmethod
    def degraded(cls, max_gen: int, predicted_completion: float = 0.0,
                 **inputs: Any) -> "AdmissionDecision":
        return cls("degrade", max_gen=int(max_gen),
                   predicted_completion=predicted_completion, **inputs)


# ---------------------------------------------------------------------------
# the Eq. 1–4 / Eq. 10–11 completion-time prediction
# ---------------------------------------------------------------------------
def memory_admits_one(core: "SchedulerCore", input_len: int,
                      first_slice: int) -> bool:
    """Eq. 5–9 batch-of-one feasibility, under the strategy's packing mode.

    With ``packing='envelope'`` the check routes through the same
    envelope-exact bound the batcher packs against — the request is
    charged exactly its own ``blocks_for(L + S)`` — so admission and
    Algorithm 1 read ONE bound (for N = 1 the two bounds coincide
    numerically; what matters is that they can never drift apart).
    """
    mem = core.mem
    if core.s.packing == "envelope":
        # validated at SchedulerCore construction: envelope => paged
        return mem.fits_envelope(
            mem.blocks_per_request(int(input_len), int(first_slice)))
    return mem.max_batch_size(int(input_len), int(first_slice)) >= 1


def predicted_queue_delay(core: "SchedulerCore") -> float:
    """Estimated core-time delay until a *new* arrival is first scheduled.

    Two observable components, both already maintained by the scheduler:

      * the least-loaded worker's outstanding estimated work — the Eq.
        10–11 load the max-min offloader adds at placement and decays at
        completion, so it is exactly the Eq. 1–4 serving-time mass ahead
        of a newcomer on the best worker;
      * the un-batched pool backlog, priced per request at one
        batch-of-one slice (Eq. 1: ``t_serve(1, L_i, S)``) and spread
        over the workers.
    """
    delay = core.offloader.min_load()
    if core.pool:
        S = core.s.slice_len
        backlog = sum(
            core.est.t_serve(1, r.effective_input_len,
                             min(S, max(r.remaining_gen, 1)))
            for r in core.pool)
        delay += backlog / core.n_workers
    return delay


def predicted_service_time(core: "SchedulerCore", input_len: int,
                           gen_cap: int) -> float:
    """Estimated core-time to serve ``gen_cap`` tokens for a fresh request
    of length ``input_len``, batch-of-one.

    ``t_serve(1, L_i, gen_cap)`` (Eq. 1–2 closed form) prices the prefill
    and every decode iteration over the growing cache; on top of that,
    each of the ``ceil(gen_cap / S) - 1`` reschedules pays its re-prefill
    of prompt + generated tokens (the paper's §3.3 slicing overhead,
    Eq. 3) and up to one Γ scheduling-interval wait.
    """
    s = core.s
    S = max(int(s.slice_len), 1)
    gen_cap = max(int(gen_cap), 1)
    t = core.est.t_serve(1, input_len, gen_cap)
    n_slices = math.ceil(gen_cap / S)
    for j in range(1, n_slices):
        t += core.est.t_prefill(1, input_len + j * S)
    t += (n_slices - 1) * s.gamma
    return t


class AdmissionController:
    """Deadline-aware admission over the scheduler's own estimators.

    Stateless apart from configuration: every ``decide`` reads the live
    core (loads, pool, predictor) so the prediction tracks the system.

    ``headroom`` scales the predicted completion before the deadline
    comparison (> 1 is more conservative); ``enabled=False`` turns the
    controller into an accept-all pass-through (used by benchmarks to
    measure the no-admission baseline).
    """

    def __init__(self, headroom: float = 1.0, enabled: bool = True):
        if headroom <= 0:
            raise ValueError(f"headroom must be positive, got {headroom}")
        self.headroom = float(headroom)
        self.enabled = bool(enabled)

    # ------------------------------------------------------------------
    def predicted_gen_cap(self, core: "SchedulerCore", input_len: int,
                          declared: int) -> int:
        """Generation-length cap used for the time prediction.

        With a prediction pipeline (``scls-pred``/``oracle``) the cap is
        the calibrated predicted remaining length — the same quantity the
        batcher uses — clipped to the declared budget.  Without one, the
        client-declared budget (``max_tokens``/``gen_len``) is all the
        scheduler may legally observe, so it is used as-is.
        """
        declared = max(int(declared), 1)
        if core.pred is None:
            return declared
        from repro.core.request import Request
        probe = Request(rid=-1, arrival=core.now, input_len=int(input_len),
                        gen_len=None, max_gen=declared)
        raw = max(float(core.pred.predictor.predict_remaining(probe)), 1.0)
        # the calibrator's multiplicative correction, without registering
        # a pending prediction for a request that may never be admitted
        cap = int(np.clip(round(raw * core.pred.calibrator.scale), 1,
                          declared))
        return cap

    def decide(self, core: "SchedulerCore", *, input_len: int,
               declared_gen: int, arrival: float,
               deadline: Optional[float] = None,
               allow_degrade: bool = False) -> AdmissionDecision:
        """Admission check for one prospective request.

        ``declared_gen`` is the client's generation budget (``max_tokens``
        / ``gen_len``), ``deadline`` an absolute core-time instant (None =
        best-effort: always admitted).  Nothing here touches the
        scheduler state — a rejected request leaves no trace beyond the
        ``n_rejected`` counter its caller increments.
        """
        if not self.enabled:
            return AdmissionDecision.accepted()
        first_slice = min(int(core.s.slice_len), max(int(declared_gen), 1))
        if not memory_admits_one(core, int(input_len), first_slice):
            return AdmissionDecision.rejected(
                f"prompt of {input_len} tokens does not fit worker memory "
                f"even as a batch of one", reason_code="memory")
        if deadline is None:
            return AdmissionDecision.accepted()

        queue_delay = predicted_queue_delay(core)
        cap = self.predicted_gen_cap(core, input_len, declared_gen)
        service = predicted_service_time(core, int(input_len), cap)
        inputs = dict(queue_delay=queue_delay, service_est=service,
                      gen_cap=cap)
        start = max(float(arrival), core.now)
        completion = start + self.headroom * (queue_delay + service)
        if completion <= deadline:
            return AdmissionDecision.accepted(predicted_completion=completion,
                                              **inputs)

        if allow_degrade:
            # longest budget that still meets the deadline (monotone in
            # gen, so bisect); degrade only when at least one slice fits
            budget = deadline - start - self.headroom * queue_delay
            lo, hi = 0, cap
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if self.headroom * predicted_service_time(
                        core, int(input_len), mid) <= budget:
                    lo = mid
                else:
                    hi = mid - 1
            if lo >= 1:
                degraded_completion = start + self.headroom * (
                    queue_delay + predicted_service_time(core, int(input_len), lo))
                return AdmissionDecision.degraded(
                    lo, predicted_completion=degraded_completion, **inputs)

        return AdmissionDecision.rejected(
            f"predicted completion {completion:.3f}s exceeds deadline "
            f"{deadline:.3f}s (queue delay {queue_delay:.3f}s, "
            f"predicted {cap} tokens)",
            predicted_completion=completion,
            retry_after=max(queue_delay, completion - deadline),
            reason_code="deadline", **inputs)


#: accept-all controller for the no-admission baseline arms
NO_ADMISSION = AdmissionController(enabled=False)
