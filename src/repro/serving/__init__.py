"""repro.serving — the request-level serving front end.

One :class:`SchedulerCore` implements the scheduling loop (arrival intake
→ predict → DP batch → max-min offload → slice dispatch → re-enqueue)
for *every* runtime; a :class:`Backend` supplies the physics
(:class:`SimBackend`: calibrated latency models in virtual time;
:class:`RealBackend`: real JAX engines, measured wall time).  On top,
:class:`SliceServer` exposes the online API a real deployment needs —
``submit`` / per-slice token streaming / ``cancel`` / ``drain`` — and
:class:`ServingConfig` is the one validated configuration object for all
of it.

The legacy offline entry points (``repro.cluster.simulator.
ClusterSimulator``, ``repro.cluster.realtime.RealCluster``) remain as
thin shims over this package.
"""
from repro.serving.backends import (Backend, BatchExecution, RealBackend,
                                    SimBackend)
from repro.serving.config import (SERVABLE_REAL, ServingConfig,
                                  default_sim_environment, fitted_estimator)
from repro.serving.core import SchedulerCore, WorkerState
from repro.serving.server import RequestHandle, SliceServer

__all__ = [
    "Backend", "BatchExecution", "RealBackend", "RequestHandle",
    "SERVABLE_REAL", "SchedulerCore", "ServingConfig", "SimBackend",
    "SliceServer", "WorkerState", "default_sim_environment",
    "fitted_estimator",
]
