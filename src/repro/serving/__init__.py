"""repro.serving — the request-level serving front end.

One :class:`SchedulerCore` implements the scheduling loop (arrival intake
→ predict → DP batch → max-min offload → slice dispatch → re-enqueue)
for *every* runtime; a :class:`Backend` supplies the physics
(:class:`SimBackend`: calibrated latency models in virtual time;
:class:`RealBackend`: real JAX engines, measured wall time).  On top:

* :class:`AsyncSliceServer` (``repro.serving.aio``) — the concurrent
  front end: a background pacer task steps the core with wall-clock
  pacing while N clients ``await handle.result()`` / ``async for tok in
  handle.tokens()``; its :class:`Session` runs multi-turn conversations
  whose history prefix the retain-mode paged backend serves from shared
  (refcounted, copy-on-write) KV pages instead of re-prefilling;
* :class:`SliceServer` — the synchronous caller-driven adapter over it
  (``submit`` / per-slice token streaming / ``cancel`` / ``drain``);
* :class:`AdmissionController` (``repro.serving.admission``) — SLO-aware
  admission: predicted queue delay + Eq. 1–4 completion estimates reject
  doomed requests (:class:`AdmissionRejected`) before any prefill;
* :class:`HTTPFrontend` (``repro.serving.http``) — a stdlib-only
  OpenAI-compatible endpoint (``POST /v1/completions`` with per-slice SSE
  streaming, ``GET /healthz``, 429 + ``Retry-After`` from admission);
* :class:`ServingConfig` — the one validated configuration object for all
  of it.

The legacy offline entry points (``repro.cluster.simulator.
ClusterSimulator``, ``repro.cluster.realtime.RealCluster``) remain as
thin shims over this package.
"""
from repro.serving.admission import (NO_ADMISSION, AdmissionController,
                                     AdmissionDecision, AdmissionRejected,
                                     predicted_queue_delay,
                                     predicted_service_time)
from repro.serving.aio import AsyncRequestHandle, AsyncSliceServer, Session
from repro.serving.backends import (Backend, BatchExecution, RealBackend,
                                    SimBackend)
from repro.serving.config import (SERVABLE_REAL, ServingConfig,
                                  default_sim_environment, fitted_estimator)
from repro.serving.core import SchedulerCore, WorkerState
from repro.serving.http import HTTPFrontend
from repro.serving.server import RequestHandle, SliceServer

__all__ = [
    "AdmissionController", "AdmissionDecision", "AdmissionRejected",
    "AsyncRequestHandle", "AsyncSliceServer", "Backend", "BatchExecution",
    "HTTPFrontend", "NO_ADMISSION", "RealBackend", "RequestHandle",
    "SERVABLE_REAL", "SchedulerCore", "ServingConfig", "Session",
    "SimBackend", "SliceServer", "WorkerState", "default_sim_environment",
    "fitted_estimator", "predicted_queue_delay", "predicted_service_time",
]
