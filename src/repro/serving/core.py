"""The ONE scheduling loop shared by every runtime (sim and real).

Before this module existed the loop (arrival intake → predict →
``dp_batch`` → offload → slice dispatch → re-enqueue) was implemented
twice — once in ``cluster/simulator.py`` (discrete events, virtual time)
and once in ``cluster/realtime.py`` (synchronous rounds over real
engines) — and the two could drift.  ``SchedulerCore`` is the merged
discrete-event engine; a :class:`~repro.serving.backends.Backend` supplies
the only parts that legitimately differ (durations and token outcomes).
``ClusterSimulator`` and ``RealCluster`` survive as thin shims.

Worker modes mirror the strategy modes (``repro.core.schedulers``):

  * perreq     — SLS/SO: requests round-robined on arrival; each worker
                 runs FCFS static batches of fixed size.
  * central    — PM/AB/LB/SCLS: a central tick drains the pool, batches,
                 and offloads whole batches to worker queues.
  * pred       — SCLS-PRED/ORACLE: central tick with calibrated predicted
                 remaining-length buckets (``core.batcher.bucketed_pred_batch``).
  * continuous — ILS: per-iteration join/exit with a conservative
                 parallelism cap (sim backend only).
  * cont_scls  — SCLS-CB: S-token slice leases on continuous batching
                 (sim backend only).

Beyond the offline ``run()``, the core is an *online* machine: requests
can be submitted at any time (``submit``), observed incrementally
(``token_log`` grows per slice), and cancelled mid-flight (``cancel`` —
the request leaves at the next slice/lease boundary, its page envelope is
freed by the backend, and the predictor is trained on the truncated
length).  :class:`repro.serving.server.SliceServer` wraps this in a
request-handle API.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.cluster.metrics import RunMetrics, compute_metrics
from repro.core.batcher import dp_batch
from repro.core.estimator import ServingTimeEstimator
from repro.core.interval import next_interval
from repro.core.memory import MemoryEstimator, PagedMemoryEstimator
from repro.core.offloader import (MaxMinOffloader, Offloader,
                                  RoundRobinOffloader)
from repro.core.request import Batch, Request, bucket_len
from repro.core.schedulers import StrategyConfig
from repro.obs import OBS_OFF, Observability
from repro.predict import LengthPredictor, PredictionPipeline
from repro.serving.backends import Backend

#: modes driven by the central scheduling tick
CENTRAL_MODES = ("central", "cont_scls", "pred")
#: modes that need Backend.supports_continuous
CONTINUOUS_MODES = ("continuous", "cont_scls")

# batch_log entry tags (the equivalence-test fingerprint format)
_LOG_STATIC = "static"
_LOG_CONT = "cont"


class WorkerState:
    """Per-worker scheduling state (queues live here; execution is the
    backend's business)."""

    __slots__ = ("wid", "queue", "pending", "running", "busy",
                 "completion_time")

    def __init__(self, wid: int):
        self.wid = wid
        self.queue: deque = deque()    # Batch (static modes)
        self.pending: deque = deque()  # Request (perreq/continuous)
        # [req, cached_len, lease_left, block_charge] (continuous modes)
        self.running: list = []
        self.busy = False
        self.completion_time = 0.0


class SchedulerCore:
    """One scheduling loop, two backends — see module docstring."""

    def __init__(self, strategy: StrategyConfig, backend: Backend,
                 n_workers: int, sched_est: ServingTimeEstimator,
                 mem: MemoryEstimator,
                 predictor: Optional[LengthPredictor] = None,
                 ils_span: int = 32,
                 obs: Optional[Observability] = None):
        if (strategy.mode in CONTINUOUS_MODES
                and not backend.supports_continuous):
            raise ValueError(
                f"strategy {strategy.name} (mode {strategy.mode!r}) needs a "
                f"continuous-capable backend; {type(backend).__name__} "
                f"supports central-tick modes only")
        if (strategy.packing == "envelope"
                and not isinstance(mem, PagedMemoryEstimator)):
            # fail at construction, not on the first scheduling tick
            raise ValueError(
                f"strategy {strategy.name} packs per-request envelopes "
                f"(packing='envelope'), which needs a PagedMemoryEstimator; "
                f"got {type(mem).__name__}")
        self.s = strategy
        self.backend = backend
        # pred mode: the shared predictor pipeline (one code path for all
        # runtimes — construction, observe→predict→calibrate→batch, feedback)
        self.pred: Optional[PredictionPipeline] = (
            PredictionPipeline(strategy, predictor)
            if strategy.mode == "pred" else None)
        self.predictor = self.pred.predictor if self.pred else None
        self.calibrator = self.pred.calibrator if self.pred else None
        self.n_workers = n_workers
        self.est = sched_est
        self.mem = mem
        self.ils_span = ils_span
        self.workers = [WorkerState(w) for w in range(n_workers)]
        self.offloader: Offloader = (
            MaxMinOffloader(n_workers) if strategy.offload == "maxmin"
            else RoundRobinOffloader(n_workers))
        # retention-affinity tiebreak (ROADMAP): a backend that can say
        # where a batch's prefix pages are resident feeds the max-min
        # offloader's ε-tiebreak.  SimBackend has no residency (attribute
        # absent -> affinity stays None and placement is bit-identical to
        # the affinity-less offloader, which the goldens pin).
        if strategy.offload == "maxmin" and hasattr(backend,
                                                    "batch_affinity"):
            self.offloader.affinity_fn = backend.batch_affinity
        self.pool: List[Request] = []
        self.now = 0.0
        self._events: list = []
        self._seq = itertools.count()
        self._rr = 0
        # time of the authoritative armed tick (None = no tick armed);
        # superseded tick events are lazily skipped when they pop
        self._armed_tick: Optional[float] = None
        self._lease_est: Dict[int, float] = {}
        # --- request registry / online state ---
        self.requests: List[Request] = []          # every submitted request
        self._by_rid: Dict[int, Request] = {}
        self.token_log: Dict[int, List[int]] = {}  # per-slice token stream
        self._finalized: Set[int] = set()
        self._cancelled: Set[int] = set()
        # progress observers: fn(kind, request) with kind "slice" (the
        # request's token stream advanced at a slice/iteration boundary)
        # or "final" (terminal).  Purely additive — the async front end
        # (repro.serving.aio) hangs its wakeups here; offline runs have
        # no observers and pay nothing.
        self._observers: List = []
        #: requests shed by the admission layer before ever reaching the
        #: scheduler (repro.serving.admission); counted here so metrics()
        #: reports them alongside the work that did run
        self.n_rejected = 0
        #: per-reason-code shed counts (e.g. {"memory": 2, "deadline": 5})
        self.reject_reasons: Dict[str, int] = {}
        # observability bundle (repro.obs): tracing + metrics + decision
        # audit.  Every hook call site guards on ``obs.enabled`` so bare
        # cores (offline paper replays, the goldens) pay one attribute
        # read per hook point; hooks are observation-only by contract —
        # the golden dispatch logs stay bit-exact with obs fully on.
        self.obs = obs if obs is not None else OBS_OFF
        if self.obs.enabled:
            self.obs.attach(self)
        # --- accounting (paper figure columns) ---
        self.batch_sizes: List[int] = []
        self.early_returns = 0
        self.total_batches = 0
        #: §3.3 rescheduling overhead: tokens prefilled beyond each
        #: request's first prefill, summed over all dispatched slices
        #: (0 for resumed residents under kv_retain="request")
        self.reprefill_tokens = 0
        #: prompt tokens satisfied by cross-request prefix-page sharing
        #: (their prefill became a page-table remap) and the pages those
        #: joins took references on, summed over all dispatched slices
        self.prefix_hit_tokens = 0
        self.shared_blocks = 0
        self.peak_parallel = 0  # max concurrent requests on one worker
        #: dispatch fingerprint: ["static", wid, rids, input_len, slice] or
        #: ["cont", wid, rids] — pinned by the equivalence golden test
        self.batch_log: List[list] = []

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload: object = None) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def _push_tick(self, t: float) -> None:
        """Arm a scheduling tick at ``t``; a tick armed for an earlier (or
        equal) time wins, and the superseded event is skipped when it
        pops — so a submission arriving before a far-future armed tick is
        scheduled at its own arrival time, not starved until that tick."""
        if self._armed_tick is not None and t >= self._armed_tick:
            return
        self._armed_tick = t
        self._push(t, "tick", t)

    def step(self) -> bool:
        """Process one event; returns False when the event queue is empty."""
        if not self._events:
            return False
        self.now, _, kind, payload = heapq.heappop(self._events)
        getattr(self, f"_on_{kind}")(payload)
        return True

    def run_until_idle(self) -> None:
        while self.step():
            pass

    # ------------------------------------------------------------------
    # request lifecycle API
    # ------------------------------------------------------------------
    def submit(self, req: Request, arrival: Optional[float] = None) -> None:
        """Admit ``req``: schedules its arrival event (never in the past)
        and guarantees a scheduling tick will see it."""
        if req.rid in self._by_rid:
            raise ValueError(f"duplicate rid {req.rid}")
        t = req.arrival if arrival is None else float(arrival)
        t = max(t, self.now)
        req.arrival = t
        self.requests.append(req)
        self._by_rid[req.rid] = req
        self._push(t, "arrival", req)
        if self.s.mode in CENTRAL_MODES:
            self._push_tick(t)  # no-op when an earlier tick is armed

    def cancel(self, rid: int) -> bool:
        """Cancel a submitted request.  Queued requests leave immediately;
        a request inside a dispatched slice or continuous lease leaves at
        the next slice/iteration boundary (its page envelope is released
        there, and the predictor records the truncated length).  Returns
        False when the request is unknown or already finished."""
        req = self._by_rid.get(rid)
        if req is None:
            return False
        if rid in self._finalized:
            # idempotent for already-cancelled; False once completed for real
            return rid in self._cancelled
        if rid in self._cancelled:
            return True
        self._cancelled.add(rid)
        for i, r in enumerate(self.pool):
            if r.rid == rid:
                self.pool.pop(i)
                self._finalize(r, completed=False)
                return True
        for w in self.workers:
            for r in list(w.pending):
                if r.rid == rid:
                    w.pending.remove(r)
                    if rid in self._lease_est:
                        # cont_scls: the lease's marginal load was charged
                        # to this worker at placement; a lease that never
                        # starts must decay it like a finished one, or the
                        # phantom load skews max-min placement and the
                        # Eq. 12 interval forever
                        self.offloader.on_batch_complete(
                            w.wid, self._lease_est.pop(rid))
                    self._finalize(r, completed=False)
                    return True
        # in flight (queued batch / dispatched slice / continuous lease)?
        # then the slice/iteration-boundary handlers finalize it
        for w in self.workers:
            if any(r.rid == rid for b in w.queue for r in b.requests):
                return True
            if any(entry[0].rid == rid for entry in w.running):
                return True
        if any(kind == "batch_done"
               and any(r.rid == rid for r in payload[1].requests)
               for _, _, kind, payload in self._events):
            return True
        # nowhere yet — only its arrival event is pending: finalize now
        self._finalize(req, completed=False)
        return True

    def is_finalized(self, rid: int) -> bool:
        return rid in self._finalized

    def add_observer(self, fn: Callable[[str, Request], None]) -> None:
        """Register a progress observer ``fn(kind, request)`` — see
        ``_observers`` in ``__init__``."""
        self._observers.append(fn)

    def _notify(self, kind: str, r: Request) -> None:
        for fn in self._observers:
            fn(kind, r)

    def _finalize(self, r: Request, completed: bool) -> None:
        """Terminal bookkeeping, exactly once per request."""
        r.done = completed
        r.cancelled = not completed
        r.finish_time = self.now
        # real tokens (if any) move to the request; sim runs keep the legacy
        # output_tokens=None (streaming consumers synthesize indices lazily)
        r.output_tokens = self.token_log.pop(r.rid, r.output_tokens)
        if self.pred is not None and (completed or r.generated > 0):
            # online-learning feedback; a cancelled request trains on its
            # truncated realized length (it *is* realized workload) — but a
            # request cancelled before generating anything carries no
            # length evidence, and recording it would log a phantom
            # 1-token completion that biases caps toward zero
            self.pred.on_complete(r)
        # per-request resources retained across slices (persistent paged
        # prefix pages under kv_retain="request") are freed exactly here —
        # the one place every terminal path goes through
        self.backend.finish_request(r)
        self._finalized.add(r.rid)
        if self.obs.enabled:
            self.obs.on_finalize(self, r, completed)
        self._notify("final", r)

    # ------------------------------------------------------------------
    # offline entry point (legacy ClusterSimulator/RealCluster semantics)
    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration: float) -> RunMetrics:
        for r in requests:
            self.requests.append(r)
            self._by_rid[r.rid] = r
            self._push(r.arrival, "arrival", r)
        if self.s.mode in CENTRAL_MODES:
            self._push_tick(0.0)
        self.run_until_idle()
        return self.metrics(duration)

    def metrics(self, duration: Optional[float] = None) -> RunMetrics:
        wct = [w.completion_time for w in self.workers]
        if duration is None:
            duration = max(wct) if wct else 0.0
        return compute_metrics(self.s.name, list(self.requests), duration,
                               wct, self.batch_sizes, self.early_returns,
                               self.total_batches,
                               n_rejected=self.n_rejected,
                               reprefill_tokens=self.reprefill_tokens,
                               reject_reasons=self.reject_reasons,
                               prefix_hit_tokens=self.prefix_hit_tokens,
                               shared_blocks=self.shared_blocks)

    # ------------------------------------------------------------------
    # event handlers
    # ------------------------------------------------------------------
    def _on_arrival(self, req: Request) -> None:
        if req.rid in self._cancelled:
            if req.rid not in self._finalized:
                self._finalize(req, completed=False)
            return
        if self.obs.enabled:
            self.obs.on_arrival(self, req)
        if self.s.mode in CENTRAL_MODES:
            self.pool.append(req)
        elif self.s.mode == "perreq":
            w = self.workers[self._rr]
            self._rr = (self._rr + 1) % self.n_workers
            w.pending.append(req)
            if not w.busy:
                self._start_static_fcfs(w)
        else:  # continuous
            w = self.workers[self._rr]
            self._rr = (self._rr + 1) % self.n_workers
            w.pending.append(req)
            if not w.busy:
                self._continuous_step(w)

    def _on_tick(self, t: Optional[float]) -> None:
        if (t is not None and self._armed_tick is not None
                and t != self._armed_tick):
            return  # superseded by a tick re-armed for an earlier time
        self._armed_tick = None
        reqs, self.pool = self.pool, []
        if reqs and self.s.mode == "cont_scls":
            # beyond-paper: max-min placement of S-token *leases*; the
            # worker itself is a continuous-batching engine, so the load a
            # lease adds is its MARGINAL cost (the N-proportional part of
            # Eq. 1-4), not the serial batch-of-one time
            singles = []
            for r in reqs:
                L = r.effective_input_len
                marginal = (self.est.t_serve(1, L, self.s.slice_len)
                            - self.est.t_serve(0, L, self.s.slice_len))
                self._lease_est[r.rid] = marginal
                singles.append(Batch(requests=[r], input_len=L,
                                     slice_len=self.s.slice_len,
                                     est_time=marginal))
            for w, b in self._assign(singles):
                wk = self.workers[w]
                wk.pending.append(b.requests[0])
                if not wk.busy:
                    self._continuous_step(wk)
        elif reqs and self.s.mode == "pred":
            # SCLS-PRED / ORACLE: calibrated predicted remaining-length
            # caps pick the buckets and per-batch slice lengths
            batches = self.pred.batches(reqs, self.est, self.mem)
            for w, b in self._assign(batches):
                wk = self.workers[w]
                wk.queue.append(b)
                if not wk.busy:
                    self._start_batch(wk)
        elif reqs:
            cap = self.s.dp_cap if self.s.dp_cap else None
            batches = dp_batch(reqs, self.s.slice_len, self.est, self.mem,
                               max_batch_size=cap, packing=self.s.packing)
            for w, b in self._assign(batches):
                wk = self.workers[w]
                wk.queue.append(b)
                if not wk.busy:
                    self._start_batch(wk)
        if self.s.adaptive_interval:
            dt = next_interval(self.offloader.min_load(), self.s.lam,
                               self.s.gamma)
        else:
            dt = self.s.gamma
        if self._more_work_expected():
            self._push_tick(self.now + dt)

    def _assign(self, batches: List[Batch]) -> List[Tuple[int, Batch]]:
        """Offloader placement with decision audit: the pre-assignment
        load snapshot plus the offloader's documented ``loads[w] +=
        est_time`` bookkeeping reconstruct the exact Eq. 11 loads each
        placement saw (``Observability.on_schedule``)."""
        if not self.obs.enabled:
            return self.offloader.assign(batches)
        loads_before = self.offloader.snapshot()
        assignments = self.offloader.assign(batches)
        self.obs.on_schedule(self, assignments, loads_before)
        return assignments

    def _more_work_expected(self) -> bool:
        if self.pool:
            return True
        if any(e[2] == "arrival" for e in self._events):
            return True
        # pending/running cover continuous-mode workers whose admission is
        # momentarily blocked (busy alone would miss leased-out work)
        if any(w.queue or w.busy or w.pending or w.running
               for w in self.workers):
            return True
        return False

    # ------------------------------------------------------------------
    # static batch serving (perreq + central + pred)
    # ------------------------------------------------------------------
    def _start_static_fcfs(self, w: WorkerState) -> None:
        if not w.pending:
            return
        n = self.s.fixed_batch_size or len(w.pending)
        group = [w.pending.popleft() for _ in range(min(n, len(w.pending)))]
        L = max(r.effective_input_len for r in group)
        b = Batch(requests=group, input_len=bucket_len(L, self.est.bucket),
                  slice_len=self.s.slice_len)
        b.est_time = self.est.t_serve(b.size, b.input_len, self.s.slice_len)
        w.queue.append(b)
        self._start_batch(w)

    def _start_batch(self, w: WorkerState) -> None:
        if w.busy or not w.queue:
            return
        b = w.queue.popleft()
        self.peak_parallel = max(self.peak_parallel, b.size)
        self.batch_log.append(
            [_LOG_STATIC, w.wid, sorted(r.rid for r in b.requests),
             int(b.input_len), int(b.slice_len)])
        prev = [self.token_log.get(r.rid, []) for r in b.requests]
        ex = self.backend.run_batch(w.wid, b, prev)
        w.busy = True
        if self.obs.enabled:
            self.obs.on_dispatch(self, w.wid, b, ex.duration, ex.prefill_dur)
        self._push(self.now + ex.duration, "batch_done", (w.wid, b, ex))

    def _on_batch_done(self, payload: Tuple[int, Batch, object]) -> None:
        wid, b, ex = payload
        w = self.workers[wid]
        w.busy = False
        w.completion_time = self.now
        self.total_batches += 1
        self.batch_sizes.append(b.size)
        self.reprefill_tokens += ex.reprefill_tokens
        self.prefix_hit_tokens += ex.prefix_hit_tokens
        self.shared_blocks += ex.shared_blocks
        if ex.early_return:
            self.early_returns += 1
        self.backend.finish_batch(wid, b)  # e.g. release page envelopes
        unfinished = []
        for r, rr in zip(b.requests, ex.per_request):
            r.n_schedules += 1
            r.pad_tokens += rr["pad"]
            r.invalid_tokens += rr["invalid"]
            gen_now, toks = rr["n_valid"], rr["tokens"]
            over = gen_now - r.remaining_gen
            if over > 0:
                # EOS-driven row (gen_len=None) overran its max_gen budget
                # within the slice: the overflow is invalid, like any token
                # generated past a request's end
                gen_now -= over
                toks = toks[:gen_now] if toks is not None else None
                r.invalid_tokens += over
            r.generated += gen_now
            if toks is not None:  # sim backend: tokens synthesized lazily
                self.token_log.setdefault(r.rid, []).extend(toks)
            if r.first_token_time is None:
                r.first_token_time = self.now
            if r.rid in self._cancelled:
                self._finalize(r, completed=False)
            elif r.remaining_gen <= 0 or (r.gen_len is None
                                          and rr.get("finished")):
                # forced-length requests run to their emulated EOS position
                # exactly; only EOS-driven ones (gen_len=None) trust the
                # engine's finished flag
                self._finalize(r, completed=True)
            else:
                unfinished.append(r)
                self._notify("slice", r)
        self.offloader.on_batch_complete(wid, b.est_time)
        if unfinished:
            if self.s.mode in ("central", "pred"):
                self.pool.extend(unfinished)
            else:  # SO: re-send round-robin
                for r in unfinished:
                    tgt = self.workers[self._rr]
                    self._rr = (self._rr + 1) % self.n_workers
                    tgt.pending.append(r)
                    if not tgt.busy:
                        self._start_static_fcfs(tgt)
        if self.obs.enabled:
            self.obs.on_slice_done(self, wid, b, ex.reprefill_tokens,
                                   ex.prefix_hit_tokens, ex.shared_blocks)
        if self.s.mode == "perreq" and w.pending and not w.busy:
            self._start_static_fcfs(w)
        elif w.queue:
            self._start_batch(w)

    # ------------------------------------------------------------------
    # continuous batching (ILS / SCLS-CB; sim backend only)
    # ------------------------------------------------------------------
    def _block_charge(self, eff_len: int) -> int:
        """kv_layout="paged": blocks the joining request's envelope holds —
        the slice lease S for cont_scls, the length-blind worst case
        (max_gen remaining) for plain ILS.  Fixed for the request's stay,
        exactly like the real engine's join-time ``reserve``."""
        if self.s.kv_layout != "paged":
            return 0
        S = (self.s.slice_len if self.s.mode == "cont_scls"
             else self.s.max_gen)
        return self.mem.blocks_per_request(eff_len, S)

    def _ils_token_budget_ok(self, w: WorkerState, newreq: Request) -> bool:
        if self.s.kv_layout == "paged":
            # block-granular admission (repro.kvcache): each running
            # request occupies exactly its reserved envelope rounded up to
            # pages; the join fits iff the worker's pool has free blocks
            assert isinstance(self.mem, PagedMemoryEstimator), \
                "kv_layout='paged' needs a PagedMemoryEstimator"
            used = sum(blocks for *_, blocks in w.running)
            charge = self._block_charge(newreq.effective_input_len)
            return used + charge <= self.mem.total_blocks
        budget = self.s.max_cached_tokens
        if budget is None and self.s.mode == "cont_scls":
            # slices bound per-request growth to eff_len + S, so the exact
            # memory budget applies (no conservative cap) — Eq. 5/9.
            # NOTE: this is the *idealized* fragmentation-free allocator;
            # kv_layout="paged" is the realizable version (block-rounded)
            if hasattr(self.mem, "m_available") and self.mem.delta_bytes > 0:
                budget = int(self.mem.zeta * self.mem.m_available
                             / self.mem.delta_bytes)
        if budget is None:
            return True
        tokens = sum(c + self.s.slice_len for _, c, _, _ in w.running)
        return tokens + newreq.effective_input_len + self.s.slice_len <= budget

    def _continuous_step(self, w: WorkerState) -> None:
        """Advance worker w: admit joins, then run a span of iterations."""
        dur = 0.0
        # admit (FCFS) under the conservative parallelism cap.  An EMPTY
        # worker always admits its head-of-line request: a request whose
        # envelope alone exceeds the budget (e.g. its effective input grew
        # past it across leases) can never fit, and gating it on the
        # budget would starve it — and everything FCFS behind it — forever
        # (the legacy simulator livelocked here; the real ContinuousEngine
        # rejects such requests up front instead).  Serving it solo is the
        # closest meaningful semantics.
        lease = self.s.mode == "cont_scls"
        while (w.pending and len(w.running) < self.s.max_parallel
               and (not w.running
                    or self._ils_token_budget_ok(w, w.pending[0]))):
            r = w.pending.popleft()
            dur += self.backend.prefill_time(r)
            r.n_schedules += 1
            w.running.append([r, r.effective_input_len,
                              self.s.slice_len if lease else (1 << 30),
                              self._block_charge(r.effective_input_len)])
        if not w.running:
            w.busy = False
            return
        w.busy = True
        span = min(self.ils_span,
                   min(min(r.remaining_gen, lease_left)
                       for r, _, lease_left, _ in w.running))
        span = max(span, 1)
        N = len(w.running)
        self.peak_parallel = max(self.peak_parallel, N)
        avg_len = float(np.mean([c for _, c, _, _ in w.running]))
        dur += self.backend.span_time(avg_len, span, N)
        self.batch_log.append(
            [_LOG_CONT, w.wid, sorted(e[0].rid for e in w.running)])
        if self.obs.enabled:
            self.obs.on_cont_dispatch(self, w.wid,
                                      [e[0].rid for e in w.running], dur)
        self._push(self.now + dur, "cont_done", (w.wid, span, N))

    def _on_cont_done(self, payload: Tuple[int, int, int]) -> None:
        wid, span, n_running = payload
        w = self.workers[wid]
        w.completion_time = self.now
        self.batch_sizes.append(n_running)
        self.total_batches += 1
        still = []
        expired = []
        for r, c, lease_left, blocks in w.running:
            r.generated += span
            lease_left -= span
            if r.first_token_time is None:
                r.first_token_time = self.now
            if r.rid in self._cancelled:
                # mid-lease cancel: leave at this iteration boundary; the
                # block charge vanishes with the running entry
                self._finalize(r, completed=False)
                self.offloader.on_batch_complete(
                    w.wid, self._lease_est.pop(r.rid, 0.0))
            elif r.remaining_gen <= 0:
                self._finalize(r, completed=True)
                self.offloader.on_batch_complete(
                    w.wid, self._lease_est.pop(r.rid, 0.0))
            elif lease_left <= 0:  # slice lease over -> back to the pool
                expired.append(r)
                self.offloader.on_batch_complete(
                    w.wid, self._lease_est.pop(r.rid, 0.0))
                self._notify("slice", r)
            else:
                still.append([r, c + span, lease_left, blocks])
                self._notify("slice", r)
        w.running = still
        if expired:
            self.pool.extend(expired)
        if self.obs.enabled:
            self.obs.on_cont_done(self, wid)
        self._continuous_step(w)
