"""Fused RoPE + page append + paged decode attention — Pallas TPU kernel.

The unfused decode step is three passes: rotate the new q/k token in
plain jnp, scatter the rotated k (and v) into its page slot with an XLA
scatter, then launch ``kernels.paged_decode_attention`` to stream every
page back out of HBM.  This kernel does all of it in ONE launch: each
(row, kv-head, page) grid step rotates the new token in-register (angle
from the scalar-prefetched ``q_pos``), injects it into the current page's
K/V tile *before* scoring (so attention sees the post-write state —
exactly the unfused ordering), folds the tile into the running softmax,
and DMA's the modified tile back through ``input_output_aliases``.  The
new token's K/V thus lands in the pool as a side effect of the attention
stream it was already paying for.

Pages of different rows are disjoint by the allocator contract, so the
per-(b,h,j) aliased tile writes never collide — except on the null page 0
shared by short rows' unowned blocks, whose contents are never observable
(masked by ``slot_pos``), same discipline as the write kernel.  The jnp
oracle is ``kernels.ref.fused_rope_decode_append_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(bt_ref, slot_ref, q_pos_ref, slot_pos_ref, q_ref, kn_ref, vn_ref,
            k_in, v_in, ko_ref, vo_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, window: Optional[int], nb: int, pg: int,
            theta: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_pos_ref[b]           # () int32 — absolute position of the token
    slot = slot_ref[b]             # () int32 — its destination logical slot
    slot_pos = slot_pos_ref[0, :]  # (pg,) — logical slots of page j

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D) unrotated
    kn = kn_ref[0, 0].astype(jnp.float32)  # (1, D) unrotated new-token K
    vn = vn_ref[0, 0]                      # (1, D) new-token V

    D = q.shape[-1]
    half = D // 2
    # identical arithmetic to models.common.apply_rope at position q_pos;
    # iota*2 rebuilds arange(0, D, 2) without capturing a traced constant
    ar = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1) * 2.0
    freqs = 1.0 / (theta ** (ar / D))            # (1, half)
    ang = q_pos.astype(jnp.float32) * freqs      # (1, half)
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    q1, q2 = q[:, :half], q[:, half:]
    qr = jnp.concatenate([q1 * cos - q2 * sin, q2 * cos + q1 * sin], axis=-1)
    k1, k2 = kn[:, :half], kn[:, half:]
    knr = jnp.concatenate([k1 * cos - k2 * sin, k2 * cos + k1 * sin], axis=-1)

    # inject the rotated new token into this page's tile iff it lives here,
    # BEFORE scoring — attention reads the post-append cache state
    row = jax.lax.broadcasted_iota(jnp.int32, (pg, 1), 0)     # (pg, 1)
    hit = (row == slot % pg) & (j == slot // pg)              # (pg, 1)
    k_tile = jnp.where(hit, knr.astype(k_in.dtype), k_in[0, :, 0])
    v_tile = jnp.where(hit, vn.astype(v_in.dtype), v_in[0, :, 0])
    ko_ref[...] = k_tile[None, :, None, :]
    vo_ref[...] = v_tile[None, :, None, :]

    s = jax.lax.dot_general(qr, k_tile.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - slot_pos < window)
    s = jnp.where(mask[None, :], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_tile.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / l_scr[...][:, None]).astype(o_ref.dtype)


def fused_rope_decode_append(q: jnp.ndarray, k_new: jnp.ndarray,
                             v_new: jnp.ndarray, block_table: jnp.ndarray,
                             slot_pos: jnp.ndarray, slots: jnp.ndarray,
                             q_pos: jnp.ndarray, k_pages: jnp.ndarray,
                             v_pages: jnp.ndarray, theta: float = 10000.0,
                             window: Optional[int] = None,
                             scale: Optional[float] = None,
                             interpret: bool = False):
    """q (B,Hq,D) and k/v_new (B,Hkv,D) *unrotated* new-token projections;
    block_table (B,nb); slot_pos (B,nb·pg) already marking the new token's
    slot (it must attend to itself); slots (B,) destination logical slot;
    q_pos (B,) absolute position (== slots in the compact layout);
    k/v_pages (P,pg,Hkv,D).  Returns (out (B,Hq,D), k_pages, v_pages)."""
    B, Hq, D = q.shape
    pg, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_table.shape[1]
    assert slot_pos.shape == (B, nb * pg), (slot_pos.shape, (B, nb * pg))
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    kg = k_new.reshape(B, Hkv, 1, D)
    vg = v_new.reshape(B, Hkv, 1, D)
    kernel = functools.partial(_kernel, scale=scale, window=window, nb=nb,
                               pg=pg, theta=float(theta))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # block_table + slots + q_pos
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, pg), lambda b, h, j, bt, sl, qp: (b, j)),
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, bt, sl, qp: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, bt, sl, qp: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, bt, sl, qp: (b, h, 0, 0)),
            # aliased pool inputs: read-modify-write of the (page, head) tile
            pl.BlockSpec((1, pg, 1, D),
                         lambda b, h, j, bt, sl, qp: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, pg, 1, D),
                         lambda b, h, j, bt, sl, qp: (bt[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, pg, 1, D),
                         lambda b, h, j, bt, sl, qp: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, pg, 1, D),
                         lambda b, h, j, bt, sl, qp: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, bt, sl, qp: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out_k, out_v, out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                   jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
                   jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype)],
        # operand indices count the scalar-prefetch args: (bt, slots, q_pos,
        # slot_pos, q, k_new, v_new, k_pages, v_pages) -> pools are 7 and 8
        input_output_aliases={7: 0, 8: 1},
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), slots.astype(jnp.int32),
      q_pos.astype(jnp.int32), slot_pos.astype(jnp.int32), qg, kg, vg,
      k_pages, v_pages)
    return out.reshape(B, Hq, D), out_k, out_v
