"""jit'd dispatch wrappers for the Pallas kernels.

``impl`` selects the backend:
  * "xla"       — the pure-jnp reference (default on CPU; also the oracle)
  * "pallas"    — the TPU kernel (compiled on TPU, interpret-executed on CPU)

``set_default_impl`` flips the global default (the engines and models call
through these wrappers, so one switch moves the whole serving stack onto
the kernels).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_prefill import flash_prefill as _prefill_pallas
from repro.kernels.fused_rope_decode_append import (
    fused_rope_decode_append as _fused_decode_pallas)
from repro.kernels.fused_rope_prefill_write import (
    fused_rope_prefill_write as _fused_write_pallas)
from repro.kernels.paged_decode_attention import (
    paged_decode_attention as _paged_decode_pallas)
from repro.kernels.paged_prefill_write import (
    paged_prefill_write as _paged_write_pallas)
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

_DEFAULT_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window", "impl", "block_q", "block_k"))
def prefill_attention(q, k, v, positions, window: Optional[int] = None,
                      impl: Optional[str] = None, block_q: int = 128,
                      block_k: int = 128):
    """Causal/pad-masked GQA prefill attention. q (B,T,Hq,D) -> (B,T,Hq,D)."""
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        T = q.shape[1]
        bq = min(block_q, T)
        bk = min(block_k, T)
        while T % bq:
            bq //= 2
        while T % bk:
            bk //= 2
        return _prefill_pallas(q, k, v, positions, window=window,
                               block_q=bq, block_k=bk, interpret=_interpret())
    return ref.flash_prefill_ref(q, k, v, positions, window=window)


@partial(jax.jit, static_argnames=("window", "impl", "block_w"))
def decode_gqa_attention(q, k_cache, v_cache, slot_pos, q_pos,
                         window: Optional[int] = None,
                         impl: Optional[str] = None, block_w: int = 512):
    """Single-token GQA decode attention. q (B,Hq,D) -> (B,Hq,D)."""
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        W = k_cache.shape[1]
        bw = min(block_w, W)
        while W % bw:
            bw //= 2
        return _decode_pallas(q, k_cache, v_cache, slot_pos, q_pos,
                              window=window, block_w=bw, interpret=_interpret())
    return ref.decode_attention_ref(q, k_cache, v_cache, slot_pos, q_pos,
                                    window=window)


@partial(jax.jit, static_argnames=("window", "impl"))
def paged_decode_attention(q, k_pages, v_pages, block_table, slot_pos, q_pos,
                           window: Optional[int] = None,
                           impl: Optional[str] = None):
    """Single-token GQA decode over a paged KV cache. q (B,Hq,D) -> (B,Hq,D).

    The page size is the kernel's cache-block size (one grid step per
    page), so no block_w knob: pick ``page_tokens`` TPU-friendly instead.
    """
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        return _paged_decode_pallas(q, k_pages, v_pages, block_table,
                                    slot_pos, q_pos, window=window,
                                    interpret=_interpret())
    return ref.paged_decode_attention_ref(q, k_pages, v_pages, block_table,
                                          slot_pos, q_pos, window=window)


@partial(jax.jit, static_argnames=("impl",))
def paged_prefill_write(k_new, v_new, positions, block_table, k_pages,
                        v_pages, impl: Optional[str] = None):
    """Write prefill K/V into the paged pool through block tables.

    k/v_new (B,T,Hkv,D) in the repo's left-padded layout; positions (B,T)
    from ``models.transformer.make_positions`` (pads < 0, real tokens at
    their absolute position — which IS the destination logical slot in
    the persistent-paged layout); block_table (B,nb); k/v_pages
    (P,pg,Hkv,D).  Returns the updated (k_pages, v_pages); pads land in
    the null page.  Tail slots of a row's last owned page differ between
    impls (the Pallas kernel copies whole pages) but are masked by
    ``slot_pos`` until decode overwrites them — never observable.
    """
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        pad = jnp.sum(positions < 0, axis=1).astype(jnp.int32)
        return _paged_write_pallas(k_new, v_new, pad, block_table,
                                   k_pages, v_pages, interpret=_interpret())
    return ref.paged_prefill_write_ref(k_new, v_new, positions, block_table,
                                       k_pages, v_pages)


@partial(jax.jit, static_argnames=("theta", "impl"))
def fused_rope_prefill_write(k_new, v_new, positions, block_table, k_pages,
                             v_pages, theta: float = 10000.0,
                             impl: Optional[str] = None):
    """Rotate prefill K at its absolute positions AND write K/V into the
    paged pool in one pass.

    k/v_new (B,T,Hkv,D) left-padded *unrotated* projections; positions
    (B,T) from ``models.transformer.make_positions`` (pads < 0, real
    tokens at their absolute position == destination logical slot);
    block_table (B,nb); k/v_pages (P,pg,Hkv,D).  Returns the updated
    (k_pages, v_pages) — V unrotated, K rotated at its slot.  Slots below
    a row's first real position (a shared-prefix tail) are preserved; the
    Pallas path requires that first position to be page-aligned (the
    engine shares whole pages only).  Tail slots of a row's last owned
    page differ between impls (the Pallas kernel copies whole pages) but
    are masked by ``slot_pos`` — never observable."""
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        T = positions.shape[1]
        pad = jnp.sum(positions < 0, axis=1).astype(jnp.int32)
        n_real = T - pad
        start = jnp.maximum(
            jnp.where(n_real > 0,
                      jnp.max(positions, axis=1).astype(jnp.int32)
                      - n_real + 1, 0), 0)
        return _fused_write_pallas(k_new, v_new, pad - start, start,
                                   block_table, k_pages, v_pages,
                                   theta=theta, interpret=_interpret())
    return ref.fused_rope_prefill_write_ref(k_new, v_new, positions,
                                            block_table, k_pages, v_pages,
                                            theta=theta)


@partial(jax.jit, static_argnames=("theta", "window", "impl"))
def fused_rope_decode_append(q, k_new, v_new, block_table, slot_pos, slots,
                             q_pos, k_pages, v_pages, theta: float = 10000.0,
                             window: Optional[int] = None,
                             impl: Optional[str] = None):
    """Rotate the new q/k token, append its K/V to its page slot, and run
    paged decode attention — all in one launch.

    q (B,Hq,D) and k/v_new (B,Hkv,D) *unrotated*; block_table (B,nb);
    slot_pos (B,nb·pg) already marking the new token's slot; slots (B,)
    destination logical slot; q_pos (B,) absolute position.  Returns
    (out (B,Hq,D), k_pages, v_pages)."""
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        return _fused_decode_pallas(q, k_new, v_new, block_table, slot_pos,
                                    slots, q_pos, k_pages, v_pages,
                                    theta=theta, window=window,
                                    interpret=_interpret())
    return ref.fused_rope_decode_append_ref(q, k_new, v_new, block_table,
                                            slot_pos, slots, q_pos,
                                            k_pages, v_pages, theta=theta,
                                            window=window)


@partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_chunked_scan(x, dt, A, B, C, chunk: int = 128,
                     impl: Optional[str] = None):
    """Mamba-2 SSD scan. x (B,T,H,P); B/C (B,T,G,N) -> (y, final_state)."""
    impl = impl or _DEFAULT_IMPL
    H = x.shape[2]
    G = B.shape[2]
    if impl == "pallas":
        Bh = jnp.broadcast_to(B[:, :, :1], B.shape[:2] + (H, B.shape[-1]))             if G == 1 else jnp.repeat(B, H // G, axis=2)
        Ch = jnp.broadcast_to(C[:, :, :1], C.shape[:2] + (H, C.shape[-1]))             if G == 1 else jnp.repeat(C, H // G, axis=2)
        return _ssd_pallas(x, dt, A, Bh, Ch, chunk, interpret=_interpret())
    from repro.kernels.ref import ssd_scan_ref
    return ssd_scan_ref(x, dt, A, B, C, chunk)
