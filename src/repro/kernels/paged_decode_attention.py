"""Batched GQA decode attention over a *paged* KV cache — Pallas TPU kernel.

Same roofline as ``kernels.decode_attention`` (τ_decode in Eq. 4 is
dominated by streaming the cache from HBM), but K/V live in a shared page
pool instead of per-row contiguous regions: logical block j of row b is
physical page ``block_table[b, j]``.  The block table is passed as a
*scalar-prefetch* operand (``pltpu.PrefetchScalarGridSpec``) so the page
indirection happens in the BlockSpec index maps — each (pg, D) K/V tile is
DMA'd straight from its physical page, touched exactly once, and folded
into a running softmax.  No (B, W) contiguous gather is ever materialized.

Grid: (B, Hkv, nb) with the page axis sequential; all G = Hq/Hkv query
heads of one kv head ride along per tile to amortize the stream.  Masking
comes from ``slot_pos`` over *logical* slots (absolute position per slot,
-1 = empty) — the same convention as the dense and ring caches, so the
null-page padding of short rows (block id 0) is masked rather than
special-cased and full/ring/paged layouts look identical to the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(bt_ref, q_pos_ref, slot_pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: Optional[int],
            nb: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_pos_ref[0]           # () int32
    slot_pos = slot_pos_ref[0, :]  # (pg,) — logical slots of page j
    q = q_ref[0, 0].astype(jnp.float32)     # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (pg, D) — gathered via bt_ref
    v = v_ref[0, :, 0].astype(jnp.float32)  # (pg, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - slot_pos < window)
    s = jnp.where(mask[None, :], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / l_scr[...][:, None]).astype(o_ref.dtype)


def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_table: jnp.ndarray,
                           slot_pos: jnp.ndarray, q_pos: jnp.ndarray,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q (B,Hq,D); k/v_pages (P,pg,Hkv,D); block_table (B,nb) int32 physical
    page per logical block (0 = null page, fully masked via slot_pos);
    slot_pos (B,nb·pg); q_pos (B,).  Returns (B,Hq,D)."""
    B, Hq, D = q.shape
    pg, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_table.shape[1]
    assert slot_pos.shape == (B, nb * pg), (slot_pos.shape, (B, nb * pg))
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_kernel, scale=scale, window=window, nb=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # block_table feeds the K/V index maps
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j, bt: (b,)),        # q_pos
            pl.BlockSpec((1, pg), lambda b, h, j, bt: (b, j)),   # slot_pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, pg, 1, D),
                         lambda b, h, j, bt: (bt[b, j], 0, h, 0)),  # k page
            pl.BlockSpec((1, pg, 1, D),
                         lambda b, h, j, bt: (bt[b, j], 0, h, 0)),  # v page
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), q_pos.astype(jnp.int32),
      slot_pos.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
