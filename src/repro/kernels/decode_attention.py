"""Batched GQA decode attention over a (ring) KV cache — Pallas TPU kernel.

τ_decode in Eq. 4 is dominated by streaming the KV cache from HBM (one
query token per request, arithmetic intensity ≈ 1); the kernel therefore
blocks over the cache axis with a running softmax so each (bw, d) KV tile
is touched exactly once, and processes all G = Hq/Hkv query heads of one
kv head per tile to amortize the stream (the G×D query block sits in VMEM
for the whole sweep).

Grid: (B, Hkv, nw) with the cache-block axis sequential; masking comes from
``slot_pos`` (absolute position per cache slot; -1 = empty), which makes
full, windowed, and ring caches all look identical to the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_pos_ref, slot_pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: Optional[int],
            nw: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_pos_ref[0]         # () int32
    slot_pos = slot_pos_ref[0, :]  # (bw,)
    q = q_ref[0, 0].astype(jnp.float32)   # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (bw, D)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (bw, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G,bw)
    mask = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - slot_pos < window)
    s = jnp.where(mask[None, :], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nw - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / l_scr[...][:, None]).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     slot_pos: jnp.ndarray, q_pos: jnp.ndarray,
                     window: Optional[int] = None, scale: Optional[float] = None,
                     block_w: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q (B,Hq,D); k/v_cache (B,W,Hkv,D); slot_pos (B,W); q_pos (B,).
    Returns (B,Hq,D)."""
    B, Hq, D = q.shape
    W, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bw = min(block_w, W)
    assert W % bw == 0, "cache width must divide block_w"
    nw = W // bw

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_kernel, scale=scale, window=window, nw=nw)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nw),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),              # q_pos
            pl.BlockSpec((1, bw), lambda b, h, j: (b, j)),         # slot_pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bw, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bw, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), slot_pos.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
