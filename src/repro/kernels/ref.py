"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_prefill_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      positions: jnp.ndarray, window: Optional[int] = None,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """Causal + left-pad-masked GQA attention.

    q (B,T,Hq,D); k/v (B,T,Hkv,D); positions (B,T) with pads < 0.
    """
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    pq = positions[:, :, None]
    pk = positions[:, None, :]
    mask = (pk >= 0) & (pk <= pq)
    if window is not None:
        mask = mask & (pq - pk < window)
    mask = mask | jnp.eye(T, dtype=bool)[None]
    qr = q.reshape(B, T, Hkv, G, D)
    s = jnp.einsum("bthgd,bshd->bhgts", qr, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, T, Hq, D).astype(q.dtype)


def paged_prefill_write_ref(k_new: jnp.ndarray, v_new: jnp.ndarray,
                            dest_slot: jnp.ndarray, block_table: jnp.ndarray,
                            k_pages: jnp.ndarray, v_pages: jnp.ndarray):
    """Scatter prefill K/V into a paged KV pool through block tables.

    k/v_new (B,T,Hkv,D); dest_slot (B,T) int32 — the *logical* cache slot
    each token lands in (< 0 = pad, routed to the null page 0 whose slots
    are permanently masked); block_table (B,nb); k/v_pages (P,pg,Hkv,D).
    Token (b,t) is written to page ``block_table[b, dest_slot//pg]`` at
    offset ``dest_slot % pg``.  Returns the updated (k_pages, v_pages) —
    the paged twin of ``attention_prefill``'s dense cache build.
    """
    B, T, Hkv, D = k_new.shape
    pg = k_pages.shape[1]
    nb = block_table.shape[1]
    valid = dest_slot >= 0
    slot = jnp.clip(dest_slot, 0, nb * pg - 1)
    page = jnp.take_along_axis(block_table, slot // pg, axis=1)
    page = jnp.where(valid, page, 0).reshape(-1)   # pads -> null page
    off = jnp.where(valid, slot % pg, 0).reshape(-1)
    k_pages = k_pages.at[page, off].set(k_new.reshape(B * T, Hkv, D))
    v_pages = v_pages.at[page, off].set(v_new.reshape(B * T, Hkv, D))
    return k_pages, v_pages


def _rope_ref(x: jnp.ndarray, positions: jnp.ndarray,
              theta: float) -> jnp.ndarray:
    """Llama half-rotation RoPE — arithmetic twin of
    ``models.common.apply_rope`` kept local so the oracle module stays
    free of model-package imports.  x (..., T, H, D); positions (..., T)."""
    D = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, D, 2, dtype=jnp.float32) / D))
    ang = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def fused_rope_prefill_write_ref(k_new: jnp.ndarray, v_new: jnp.ndarray,
                                 positions: jnp.ndarray,
                                 block_table: jnp.ndarray,
                                 k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                                 theta: float = 10000.0):
    """Rotate prefill K at its absolute positions, then scatter K/V into
    the paged pool — the one-pass fused kernel's ground truth.

    k/v_new (B,T,Hkv,D) *unrotated*; positions (B,T) (pads < 0, real
    tokens at their absolute position == destination logical slot);
    block_table (B,nb); k/v_pages (P,pg,Hkv,D).  Returns the updated
    (k_pages, v_pages); V is written unrotated."""
    kr = _rope_ref(k_new, jnp.maximum(positions, 0), theta)
    return paged_prefill_write_ref(kr, v_new, positions, block_table,
                                   k_pages, v_pages)


def fused_rope_decode_append_ref(q: jnp.ndarray, k_new: jnp.ndarray,
                                 v_new: jnp.ndarray, block_table: jnp.ndarray,
                                 slot_pos: jnp.ndarray, slots: jnp.ndarray,
                                 q_pos: jnp.ndarray, k_pages: jnp.ndarray,
                                 v_pages: jnp.ndarray, theta: float = 10000.0,
                                 window: Optional[int] = None,
                                 scale: Optional[float] = None):
    """Rotate the new q/k token at ``q_pos``, append its K/V to page slot
    ``slots``, then run paged decode attention over the post-append pool —
    the fused decode kernel's ground truth.

    q (B,Hq,D) and k/v_new (B,Hkv,D) *unrotated*; slot_pos (B,nb·pg)
    already marks the new token's slot (it attends to itself); slots (B,)
    destination logical slot; q_pos (B,).  Returns
    (out (B,Hq,D), k_pages, v_pages)."""
    qr = _rope_ref(q[:, None], q_pos[:, None], theta)[:, 0]
    knr = _rope_ref(k_new[:, None], q_pos[:, None], theta)[:, 0]
    pg = k_pages.shape[1]
    page = jnp.take_along_axis(block_table, (slots // pg)[:, None],
                               axis=1)[:, 0]
    off = slots % pg
    k_pages = k_pages.at[page, off].set(knr.astype(k_pages.dtype))
    v_pages = v_pages.at[page, off].set(v_new.astype(v_pages.dtype))
    out = paged_decode_attention_ref(qr, k_pages, v_pages, block_table,
                                     slot_pos, q_pos, window=window,
                                     scale=scale)
    return out, k_pages, v_pages


def paged_decode_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                               v_pages: jnp.ndarray, block_table: jnp.ndarray,
                               slot_pos: jnp.ndarray, q_pos: jnp.ndarray,
                               window: Optional[int] = None,
                               scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token GQA decode over a *paged* KV cache.

    q (B,Hq,D); k/v_pages (P,pg,Hkv,D); block_table (B,nb) physical page per
    logical block; slot_pos (B,nb·pg) (-1 empty); q_pos (B,).
    Materializes the per-row gather the Pallas kernel streams page by page.
    """
    B = q.shape[0]
    pg, Hkv, D = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    nb = block_table.shape[1]
    k_cache = k_pages[block_table].reshape(B, nb * pg, Hkv, D)
    v_cache = v_pages[block_table].reshape(B, nb * pg, Hkv, v_pages.shape[-1])
    return decode_attention_ref(q, k_cache, v_cache, slot_pos, q_pos,
                                window=window, scale=scale)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, slot_pos: jnp.ndarray,
                         q_pos: jnp.ndarray, window: Optional[int] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token GQA decode over a (ring) KV cache.

    q (B,Hq,D); k/v_cache (B,W,Hkv,D); slot_pos (B,W) (-1 empty);
    q_pos (B,).  Returns (B,Hq,D).
    """
    B, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    mask = (slot_pos >= 0) & (slot_pos <= q_pos[:, None])
    if window is not None:
        mask = mask & (q_pos[:, None] - slot_pos < window)
    qr = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bwhd->bhgw", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgw,bwhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, D).astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 B: jnp.ndarray, C: jnp.ndarray, Q: int,
                 init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan (Mamba-2, arXiv:2405.21060) — the ``ssd_scan``
    kernel's oracle and the XLA dispatch path.

    x (B,T,H,P); dt (B,T,H) >=0 (0 at pads); A (H,) negative; B,C (B,T,G,N).
    Returns (y (B,T,H,P), final_state (B,H,P,N)).  T % Q must be 0.
    """
    Bsz, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    nc = T // Q
    rep = H // G
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = B.reshape(Bsz, nc, Q, G, N)
    Cc = C.reshape(Bsz, nc, Q, G, N)

    log_a = dtc * A  # (B,nc,Q,H), <= 0
    cum = jnp.cumsum(log_a, axis=2)  # inclusive cumsum within chunk
    # intra-chunk (attention-like): y[t] += sum_{s<=t} (C_t.B_s) e^{cum_t-cum_s} dt_s x_s
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)  # (B,nc,G,Q,Q)
    CB = jnp.repeat(CB, rep, axis=2)  # (B,nc,H,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,Q,Q,H) t,s
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    w = CB * jnp.transpose(decay, (0, 1, 4, 2, 3)) * causal[None, None, None]
    w = w * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt_s
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w, xc)
    # chunk states: S_c = sum_s e^{cum_end - cum_s} dt_s B_s (x) x_s
    seg = jnp.exp(cum[:, :, -1:, :] - cum) * dtc  # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", seg, Bh, xc)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), S.dtype)

    def step(h, xs):
        dec, s = xs  # dec (B,H), s (B,H,P,N)
        h_new = h * dec[:, :, None, None] + s
        return h_new, h  # emit state *entering* the chunk

    final, h_in = jax.lax.scan(step, init_state,
                               (chunk_decay.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)
    # inter-chunk contribution: y[t] += C_t . (e^{cum_t} * h_in)
    Ch = jnp.repeat(Cc, rep, axis=3)  # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch, h_in) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y, final
