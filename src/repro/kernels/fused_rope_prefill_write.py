"""Fused RoPE + prefill K/V page-pool scatter — Pallas TPU kernel.

The unfused persistent-paged prefill makes two passes over K: rotate in
plain jnp (materializing a rotated-K tensor the size of the prompt), then
call ``kernels.paged_prefill_write`` to copy it into pages.  This kernel
folds both into ONE pass: each (row, logical block) grid step loads the
raw projected K tile, rotates it in-register at its *destination slot*
positions (compact paged layout: logical slot == absolute position, so
the rotation angle is derivable from the grid index alone), and DMA's the
rotated K plus the untouched V straight into their physical pages via
``input_output_aliases`` — no rotated-K tensor ever exists in HBM.

Addressing: token destined for logical slot ``s`` of row ``b`` sits at
padded input index ``shift_b + s`` where ``shift_b = pad_b - start_b``
(``start_b`` = the row's first novel slot: 0 for a full prefill, the
resident-prefix length for a shared-prefix tail).  Slots below
``start_b`` belong to retained/shared pages and are passed through from
the aliased pool input unchanged.  The Pallas path requires ``start_b``
to be page-aligned (the engine shares whole pages only — PR 7 contract);
the jnp oracle ``kernels.ref.fused_rope_prefill_write_ref`` handles
arbitrary offsets.  Tail slots past the row's real length copy garbage
into the last owned page (or null page 0) — masked by ``slot_pos``
until overwritten, never observable, same discipline as the unfused
write kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _kernel(bt_ref, shift_ref, start_ref, k_ref, v_ref, k_in, v_in,
            ko_ref, vo_ref, *, pg: int, theta: float, rd_max: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    base = j * pg  # first logical slot of this block == absolute position
    # tokens for slots [base, base+pg) sit at padded indices shift_b + slot;
    # fully-passthrough blocks (below start) may index before the buffer —
    # clamp; their loaded data is discarded by the novel mask below
    rd = jnp.clip(shift_ref[b] + base, 0, rd_max)
    idx = (slice(None), pl.ds(rd, pg), slice(None), slice(None))
    k = pl.load(k_ref, idx)[0].astype(jnp.float32)  # (pg, Hkv, D)
    v = pl.load(v_ref, idx)[0]                      # (pg, Hkv, D)

    D = k.shape[-1]
    half = D // 2
    slot = base + jax.lax.broadcasted_iota(jnp.int32, (pg, 1), 0)  # (pg, 1)
    # identical arithmetic to models.common.apply_rope, angle from the
    # destination slot (== absolute position in the compact paged layout);
    # iota*2 rebuilds arange(0, D, 2) without capturing a traced constant
    ar = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1) * 2.0
    freqs = 1.0 / (theta ** (ar / D))                    # (1, half)
    ang = slot.astype(jnp.float32) * freqs               # (pg, half)
    cos = jnp.cos(ang)[:, None, :]                       # (pg, 1, half)
    sin = jnp.sin(ang)[:, None, :]
    k1 = k[..., :half]
    k2 = k[..., half:]
    kr = jnp.concatenate([k1 * cos - k2 * sin, k2 * cos + k1 * sin], axis=-1)

    novel = (slot >= start_ref[b])[:, :, None]  # (pg, 1, 1)
    ko_ref[...] = jnp.where(novel, kr.astype(ko_ref.dtype), k_in[0])[None]
    vo_ref[...] = jnp.where(novel, v, v_in[0])[None]


def fused_rope_prefill_write(k_new: jnp.ndarray, v_new: jnp.ndarray,
                             shift: jnp.ndarray, start: jnp.ndarray,
                             block_table: jnp.ndarray, k_pages: jnp.ndarray,
                             v_pages: jnp.ndarray, theta: float = 10000.0,
                             interpret: bool = False):
    """k/v_new (B,T,Hkv,D) left-padded *unrotated* prefill K/V;
    shift (B,) int32 = ``pad - start`` (read offset: slot ``s`` reads
    padded index ``shift + s``); start (B,) int32 first novel slot
    (page-aligned; slots below it are preserved from the pool);
    block_table (B,nb); k/v_pages (P,pg,Hkv,D).  Rotates K at its
    destination position in-register and returns the updated
    (k_pages, v_pages) in one pass."""
    B, T, Hkv, D = k_new.shape
    P, pg = k_pages.shape[0], k_pages.shape[1]
    nb = block_table.shape[1]
    # reads span shift_b + slot with slot < nb*pg and shift_b <= T, so pad
    # the token axis like the unfused kernel to keep every load in bounds
    overhang = nb * pg
    kp = jnp.pad(k_new, ((0, 0), (0, overhang), (0, 0), (0, 0)))
    vp = jnp.pad(v_new, ((0, 0), (0, overhang), (0, 0), (0, 0)))
    Tp = T + overhang

    kernel = functools.partial(_kernel, pg=pg, theta=float(theta),
                               rd_max=Tp - pg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # block_table + shift + start
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, Tp, Hkv, D),
                         lambda b, j, bt, sh, st: (b, 0, 0, 0)),
            pl.BlockSpec((1, Tp, Hkv, D),
                         lambda b, j, bt, sh, st: (b, 0, 0, 0)),
            # aliased pool inputs: read for passthrough of non-novel slots
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, sh, st: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, sh, st: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, sh, st: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, sh, st: (bt[b, j], 0, 0, 0)),
        ],
        scratch_shapes=[],
    )
    out_k, out_v = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                   jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)],
        # operand indices count the scalar-prefetch args: (bt, shift, start,
        # k, v, k_pages, v_pages) -> pools are operands 5 and 6
        input_output_aliases={5: 0, 6: 1},
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), shift.astype(jnp.int32),
      start.astype(jnp.int32), kp, vp, k_pages, v_pages)
    return out_k, out_v
