"""Prefill K/V page-pool scatter — Pallas TPU kernel.

The persistent-paged serving path (`engine.static_engine`, kv_retain=
"request") keeps K/V in a shared page pool across slices, so prefill must
land its K/V *in pages* rather than in a per-batch contiguous buffer.
This kernel is the write half of that path: the page-gather twin of
``kernels.paged_decode_attention`` — one grid step per (row, logical
block), with the block table and each row's left-pad offset as
scalar-prefetch operands so the physical destination page is resolved in
the output BlockSpec index map and each (pg, Hkv·D) tile is DMA'd exactly
once.  The page pools are updated *in place* via ``input_output_aliases``
(no copy of a pool that is most of HBM).

Masking discipline: tokens of logical block j of row b live at padded
input positions ``pad_b + j·pg .. pad_b + (j+1)·pg - 1`` (left padding),
so a block copy past the row's real length writes garbage into the tail
of its last owned page (or, for blocks past the row's page list, into the
null page 0) — both are unreachable, because readers mask by ``slot_pos``
and decode overwrites a slot before ever unmasking it.  The pure-jnp
oracle is ``kernels.ref.paged_prefill_write_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _kernel(bt_ref, pad_ref, k_ref, v_ref, _ko_alias, _vo_alias,
            ko_ref, vo_ref, *, pg: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    start = pad_ref[b] + j * pg  # row's tokens start after its left pad
    idx = (slice(None), pl.ds(start, pg), slice(None), slice(None))
    ko_ref[...] = pl.load(k_ref, idx)
    vo_ref[...] = pl.load(v_ref, idx)


def paged_prefill_write(k_new: jnp.ndarray, v_new: jnp.ndarray,
                        pad: jnp.ndarray, block_table: jnp.ndarray,
                        k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                        interpret: bool = False):
    """k/v_new (B,T,Hkv,D) left-padded prefill K/V; pad (B,) int32 left-pad
    width per row (= T - len); block_table (B,nb); k/v_pages (P,pg,Hkv,D).
    Token at padded index ``pad_b + s`` lands in page
    ``block_table[b, s // pg]`` at offset ``s % pg``.  Returns the updated
    (k_pages, v_pages)."""
    B, T, Hkv, D = k_new.shape
    P, pg = k_pages.shape[0], k_pages.shape[1]
    nb = block_table.shape[1]
    # block reads start at pad_b + j*pg with pad_b <= T, so the last block
    # can read up to T + nb*pg (its tail slots are masked garbage); pad the
    # token axis so every read stays in bounds
    overhang = nb * pg
    kp = jnp.pad(k_new, ((0, 0), (0, overhang), (0, 0), (0, 0)))
    vp = jnp.pad(v_new, ((0, 0), (0, overhang), (0, 0), (0, 0)))
    Tp = T + overhang

    kernel = functools.partial(_kernel, pg=pg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table + pad feed the index maps
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, Tp, Hkv, D), lambda b, j, bt, pad: (b, 0, 0, 0)),
            pl.BlockSpec((1, Tp, Hkv, D), lambda b, j, bt, pad: (b, 0, 0, 0)),
            # aliased pool inputs: same tile the kernel writes (never read)
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, pad: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, pad: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, pad: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, pg, Hkv, D),
                         lambda b, j, bt, pad: (bt[b, j], 0, 0, 0)),
        ],
        scratch_shapes=[],
    )
    out_k, out_v = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                   jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)],
        # operand indices count the scalar-prefetch args: (bt, pad, k, v,
        # k_pages, v_pages) -> pools are operands 4 and 5
        input_output_aliases={4: 0, 5: 1},
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), pad.astype(jnp.int32), kp, vp,
      k_pages, v_pages)
    return out_k, out_v
