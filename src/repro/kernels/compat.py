"""Version-compat shims for the Pallas TPU API surface."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax<=0.4.x names it TPUCompilerParams; newer releases CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def compiler_params(**kwargs):
    """Build TPU compiler params for ``pl.pallas_call`` across jax versions."""
    if _CompilerParams is None:  # pragma: no cover
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; this jax version is unsupported (need "
            ">=0.4.36)")
    return _CompilerParams(**kwargs)
