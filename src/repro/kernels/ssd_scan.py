"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

The SSD mixer is the whole compute of the attention-free arch
(mamba2-130m), and its chunked formulation maps cleanly onto TPU tiles:
per (batch, head) the grid walks chunks sequentially, carrying the (P, N)
state in VMEM scratch; within a chunk everything is (Q, ·) matmuls on the
MXU (Q = 128 aligns with the 128-lane register file):

  y[t] = Σ_{s<=t} (C_t·B_s) e^{cum_t - cum_s} dt_s x_s   (intra, tril-masked)
       + C_t · (e^{cum_t} ⊙ state_in)                     (inter)
  state_out = e^{cum_Q} state_in + Σ_s e^{cum_Q - cum_s} dt_s B_s ⊗ x_s

Numerics follow ref.ssd_scan_ref (the oracle) exactly: fp32
throughout the recurrence, single-group B/C shared across heads is handled
by the caller broadcasting (this kernel takes per-head B/C blocks).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_scr, *, nc: int, Q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    A = a_ref[0]  # scalar (this head's A, negative)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)  # (Q,)
    B = b_ref[0, 0, :, 0].astype(jnp.float32)    # (Q, N)
    C = c_ref[0, 0, :, 0].astype(jnp.float32)    # (Q, N)

    log_a = dt * A                               # (Q,) <= 0
    cum = jnp.cumsum(log_a)                      # inclusive
    # intra-chunk: G[t,s] = (C_t.B_s) e^{cum_t-cum_s} dt_s, s<=t
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    G = jnp.where(tril, CB * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,P)
    # inter-chunk: y[t] += e^{cum_t} C_t . state_in  (state (P,N))
    state = state_scr[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q,N)x(P,N) -> (Q,P)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)
    # state update: e^{cum_Q} state + Σ_s w_s x_s (x) B_s,  w = e^{cum_Q-cum} dt
    w = jnp.exp(cum[Q - 1] - cum) * dt                    # (Q,)
    upd = jax.lax.dot_general(x * w[:, None], B, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P,N)
    state = state * jnp.exp(cum[Q - 1]) + upd
    state_scr[...] = state

    @pl.when(c_idx == nc - 1)
    def _final():
        state_out_ref[0, 0] = state.astype(state_out_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,T,H,P); dt (B,T,H); A (H,); B/C (B,T,H,N) (caller broadcasts
    groups to heads).  Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    Bsz, T, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, T)
    assert T % Q == 0, "T must divide the chunk size"
    nc = T // Q
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = B.reshape(Bsz, nc, Q, H, N)
    Cc = C.reshape(Bsz, nc, Q, H, N)

    kernel = functools.partial(_kernel, nc=nc, Q=Q)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,)),                  # A
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, c, 0, h)),  # dt
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, h, c: (b, c, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nc, Q, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A.astype(jnp.float32), xc, dtc, Bc, Cc)
    return y.reshape(Bsz, T, H, P), state
