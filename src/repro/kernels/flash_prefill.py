"""Flash attention for the prefill phase — Pallas TPU kernel.

The static-batching prefill is the compute hot spot SCLS schedules around
(T_prefill in Eq. 3 — recomputed at every reschedule), so it gets a proper
TPU kernel: blockwise causal attention with running-softmax accumulation.

TPU adaptation (DESIGN.md §4): Q/K tiles are (128, head_dim) MXU-aligned;
the grid is (B, Hq, nq, nk) with the trailing kv-block axis sequential so
the (bq, d) fp32 accumulator + (bq,) running max/sum live in VMEM scratch
across kv steps.  Left-pad masking and sliding windows are folded into the
block mask via per-token positions; fully-masked kv blocks are skipped
(block-level causal early-out).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: Optional[int],
            bq: int, bk: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos_q = pos_q_ref[0, :]  # (bq,)
    pos_k = pos_k_ref[0, :]  # (bk,)
    # block-level early out: the whole kv block is strictly after every query
    block_live = jnp.min(pos_k) <= jnp.max(pos_q)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (pos_k[None, :] >= 0) & (pos_k[None, :] <= pos_q[:, None])
        if window is not None:
            mask = mask & (pos_q[:, None] - pos_k[None, :] < window)
        # allow self-slot for fully-padded query rows (avoids 0/0)
        qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = mask | (qi == ki)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / l_scr[...][:, None]).astype(o_ref.dtype)


def flash_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  positions: jnp.ndarray, window: Optional[int] = None,
                  scale: Optional[float] = None, block_q: int = 128,
                  block_k: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q (B,T,Hq,D); k/v (B,T,Hkv,D); positions (B,T). Returns (B,T,Hq,D)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, T)
    bk = min(block_k, T)
    assert T % bq == 0 and T % bk == 0, "T must divide the block sizes"
    nq, nk = T // bq, T // bk

    # layout: (B, H, T, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),       # pos_q
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),       # pos_k
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(positions.astype(jnp.int32), positions.astype(jnp.int32), qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
