"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679]."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256000, tie_embeddings=False,
    act="silu", dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=192, n_heads=6, n_kv_heads=2,
                          head_dim=32, d_ff=384, vocab_size=512,
                          dtype=jnp.float32)
