"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088]."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    n_experts=8, top_k=2, d_ff_expert=16384, capacity_factor=1.25,
    sliding_window=4096, tie_embeddings=False, act="silu",
    dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab_size=512,
                          n_experts=4, top_k=2, d_ff_expert=128,
                          capacity_factor=4.0,
                          sliding_window=64, dtype=jnp.float32)
