"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=128, ssm_n_groups=1, dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, vocab_size=512,
                          ssm_state=16, ssm_head_dim=32, ssm_chunk=16,
                          dtype=jnp.float32)
