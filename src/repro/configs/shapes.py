"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

Decode shapes lower ``serve_step`` (one new token + KV cache of seq_len);
train_4k lowers ``train_step``; prefill_32k lowers the prefill step.
long_500k substitutes a sliding window on full-attention archs
(cfg.long_context_window) — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def effective_window(cfg: ModelConfig, shape: InputShape):
    """Window override for long-context decode on full-attention archs."""
    if shape.name != "long_500k":
        return cfg.sliding_window
    if cfg.family in ("ssm", "hybrid"):
        return cfg.sliding_window  # native sub-quadratic
    if cfg.sliding_window is not None:
        return cfg.sliding_window  # e.g. mixtral SWA
    return cfg.long_context_window


def token_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.int32
                ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, T = shape.global_batch, shape.seq_len
    f = jnp.bfloat16 if jnp.dtype(cfg.dtype) == jnp.bfloat16 else cfg.dtype
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: Dict[str, Any] = {"tokens": sds((B, T), dtype)}
        if cfg.family == "encdec":
            batch["src_embeds"] = sds((B, T, cfg.d_model), f)
        if cfg.family == "vlm":
            batch["tokens"] = sds((B, T - cfg.n_prefix_tokens), dtype)
            batch["prefix_embeds"] = sds((B, cfg.n_prefix_tokens, cfg.d_model), f)
        return batch
    # serving shapes: prefill input or decode-step token batch
    batch = {"tokens": sds((B, T), dtype), "lengths": sds((B,), jnp.int32)}
    if cfg.family == "encdec":
        # decode against a fixed 4096-frame encoder memory (DESIGN.md §5)
        batch["src_embeds"] = sds((B, 4096, cfg.d_model), f)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = sds((B, cfg.n_prefix_tokens, cfg.d_model), f)
    return batch
