"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596].

Backbone only: the mel-spectrogram/conv frontend is stubbed; input_specs
provides precomputed frame embeddings (B, T_frames, d_model).
24 encoder + 24 decoder layers per the model card.
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=48, n_enc_layers=24, n_dec_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206, tie_embeddings=True,
    act="silu", dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=4, n_enc_layers=2, n_dec_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                          d_ff=256, vocab_size=512, dtype=jnp.float32)
