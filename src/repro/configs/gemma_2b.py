"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, tie_embeddings=True,
    act="gelu", scale_embed=True, dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
                          head_dim=32, d_ff=256, vocab_size=512,
                          dtype=jnp.float32)
