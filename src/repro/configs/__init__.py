"""Architecture config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from repro.models.common import ModelConfig

from repro.configs import (codeqwen1_5_7b, deepseek_v2_lite_16b, gemma_2b,
                           llama3_2_1b, mamba2_130m, minitron_4b,
                           mixtral_8x22b, paligemma_3b, recurrentgemma_9b,
                           seamless_m4t_large_v2)
from repro.configs.shapes import SHAPES, InputShape, effective_window, token_specs

_MODULES = {
    "llama3.2-1b": llama3_2_1b,
    "mamba2-130m": mamba2_130m,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "paligemma-3b": paligemma_3b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "gemma-2b": gemma_2b,
    "minitron-4b": minitron_4b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "mixtral-8x22b": mixtral_8x22b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.reduced() if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCHS}
