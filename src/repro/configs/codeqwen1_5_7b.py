"""codeqwen1.5-7b [dense] — qwen1.5 arch (QKV bias, MHA)
[hf:Qwen/CodeQwen1.5-7B]."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416, rope_theta=1000000.0,
    tie_embeddings=False, qkv_bias=True, act="silu", dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
                          head_dim=32, d_ff=512, vocab_size=512,
                          dtype=jnp.float32)
