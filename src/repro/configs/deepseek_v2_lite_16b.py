"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434].  The source paper's Lite config is 64 routed experts
(the assignment line's "160 routed" belongs to the full V2); layer 0 is a
dense MLP (d_ff 10944), experts use d_ff 1408.
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab_size=102400,
    n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    capacity_factor=1.25, first_dense_layers=1,
    use_mla=True, kv_lora_rank=512, qk_nope_head_dim=128,
    qk_rope_head_dim=64, v_head_dim=128,
    tie_embeddings=False, act="silu", dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, d_ff=256,
                          vocab_size=512, n_experts=4, n_shared_experts=1,
                          top_k=2, d_ff_expert=64, first_dense_layers=1,
                          capacity_factor=4.0,
                          kv_lora_rank=32, qk_nope_head_dim=16,
                          qk_rope_head_dim=8, v_head_dim=16,
                          dtype=jnp.float32)
