"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1 attn : 2 recurrent
[arXiv:2402.19427].  38 layers = 12 (rec,rec,attn) groups + 2 recurrent tail."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000, rg_lru_width=4096, local_window=2048,
    tie_embeddings=True, act="gelu", scale_embed=True, dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=5, d_model=128, n_heads=4, n_kv_heads=1,
                          head_dim=32, d_ff=256, vocab_size=512,
                          rg_lru_width=128, local_window=64,
                          dtype=jnp.float32)
