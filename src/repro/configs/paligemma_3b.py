"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726].

Backbone only: the SigLIP vision tower + projector are stubbed; input_specs
provides 256 precomputed patch embeddings (B, 256, d_model) consumed as a
bidirectional prefix (prefix-LM masking).
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, tie_embeddings=True,
    act="gelu", scale_embed=True, n_prefix_tokens=256, dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
                          head_dim=32, d_ff=256, vocab_size=512,
                          n_prefix_tokens=16, dtype=jnp.float32)
