"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B]."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0,
    tie_embeddings=True, act="silu", dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
                          head_dim=32, d_ff=512, vocab_size=512,
                          dtype=jnp.float32)
