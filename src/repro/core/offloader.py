"""Balanced load-oriented offloading (paper §4.5) + round-robin baseline."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.request import Batch


class Offloader:
    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self.loads: Dict[int, float] = {w: 0.0 for w in range(n_workers)}
        #: retention-affinity hook (ROADMAP; wired by SchedulerCore when
        #: the backend exposes ``batch_affinity``): ``fn(batch) ->
        #: Optional[wid]`` naming the worker where the batch's prefix
        #: pages are resident.  ``None`` (default, and for every batch
        #: without resident pages) leaves placement untouched.
        self.affinity_fn = None

    def assign(self, batches: Sequence[Batch]) -> List[Tuple[int, Batch]]:
        raise NotImplementedError

    def on_batch_complete(self, worker: int, est_time: float) -> None:
        """Eq. 11 follow-up: subtract the estimate on completion so the
        estimation error never accumulates in the load."""
        self.loads[worker] = max(0.0, self.loads[worker] - est_time)

    def snapshot(self) -> Dict[int, float]:
        """Copy of the per-worker Eq. 11 loads at this instant.  Both
        policies charge ``est_time`` per batch in assignment order, so a
        pre-``assign`` snapshot plus that bookkeeping replays the exact
        loads each placement decision saw — the decision-audit input
        (``repro.obs``)."""
        return dict(self.loads)

    def min_load(self) -> float:
        return min(self.loads.values())


class MaxMinOffloader(Offloader):
    """Longest-estimated batch -> least-loaded worker (max-min policy).

    Retention-affinity tiebreak: when ``affinity_fn`` names a worker whose
    resident prefix pages cover this batch and that worker's Eq. 11 load
    is within ``epsilon · est_time`` of the minimum, it wins the placement
    — the batch's prefill becomes a page-table remap there, while a
    cross-worker move would release those pages and re-prefill from
    scratch.  With no affinity source (or ``None`` per batch) placement
    is bit-identical to the plain policy, which the golden dispatch logs
    pin.
    """

    def __init__(self, n_workers: int, epsilon: float = 0.25):
        super().__init__(n_workers)
        if epsilon < 0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        self.epsilon = float(epsilon)

    def assign(self, batches: Sequence[Batch]) -> List[Tuple[int, Batch]]:
        out = []
        for b in sorted(batches, key=lambda b: -b.est_time):
            w = min(self.loads, key=self.loads.get)
            if self.affinity_fn is not None:
                pref = self.affinity_fn(b)
                if (pref is not None and pref != w and pref in self.loads
                        and self.loads[pref] <= self.loads[w]
                        + self.epsilon * b.est_time):
                    w = pref
            self.loads[w] += b.est_time  # Eq. 11
            out.append((w, b))
        return out


class RoundRobinOffloader(Offloader):
    """SLS/ILS baseline policy.  Loads are still tracked (for Eq. 12 and
    metrics) but do not influence placement."""

    def __init__(self, n_workers: int):
        super().__init__(n_workers)
        self._next = 0

    def assign(self, batches: Sequence[Batch]) -> List[Tuple[int, Batch]]:
        out = []
        for b in batches:
            w = self._next
            self._next = (self._next + 1) % self.n_workers
            self.loads[w] += b.est_time
            out.append((w, b))
        return out
