"""Adaptive schedule-interval update (paper §4.6, Eq. 12)."""
from __future__ import annotations


def next_interval(min_worker_load: float, lam: float, gamma: float) -> float:
    """T <- max(λ · min_w load(w), Γ)."""
    return max(lam * min_worker_load, gamma)
