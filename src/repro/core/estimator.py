"""Serving-time estimator (paper §4.2, Eq. 1–4).

  T_prefill(N, L) = p1·N·L + p2·N + p3·L + p4               (Eq. 3)
  τ_decode(l, N)  = d1·N·l + d2·N + d3·l + d4               (Eq. 4)
  T_serve(N, L_i, L_o) = T_prefill + Σ_{l=1..L_o} τ(L_i+l, N)   (Eq. 1–2)

The decode sum has the closed form used below (τ is affine in l), so the
O(n²) DP batcher evaluates T_serve in O(1).  Coefficients are fit by linear
least squares on one-time per-iteration profiles — no re-profiling when the
slice length changes (the paper's key practicality argument).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

import numpy as np

from repro.core.request import bucket_len


@dataclasses.dataclass
class LatencyCoeffs:
    """(c1·N·L + c2·N + c3·L + c4) coefficient quadruple."""

    c1: float
    c2: float
    c3: float
    c4: float

    def __call__(self, N, L):
        return self.c1 * N * L + self.c2 * N + self.c3 * L + self.c4

    def as_array(self) -> np.ndarray:
        return np.array([self.c1, self.c2, self.c3, self.c4])


def fit_bilinear(samples: Iterable[Tuple[float, float, float]]) -> Tuple[LatencyCoeffs, float]:
    """samples: (N, L, seconds) -> (coeffs, rmse)."""
    pts = np.asarray(list(samples), dtype=np.float64)
    N, L, t = pts[:, 0], pts[:, 1], pts[:, 2]
    X = np.stack([N * L, N, L, np.ones_like(N)], axis=1)
    beta, *_ = np.linalg.lstsq(X, t, rcond=None)
    resid = X @ beta - t
    rmse = float(np.sqrt(np.mean(resid ** 2)))
    return LatencyCoeffs(*beta), rmse


@dataclasses.dataclass
class ServingTimeEstimator:
    prefill: LatencyCoeffs
    decode: LatencyCoeffs
    bucket: int = 1  # TPU shape-bucketing (DESIGN.md §8); 1 = paper-exact

    # -- paper Eq. 3 --
    def t_prefill(self, N: int, L_i: int) -> float:
        return max(self.prefill(N, bucket_len(L_i, self.bucket)), 0.0)

    # -- paper Eq. 4 --
    def tau_decode(self, l: int, N: int) -> float:
        return max(self.decode(N, l), 0.0)

    # -- paper Eq. 2 closed form:
    #   Σ_{l=1..S} τ(L+l, N) = S·(d2·N + d4) + (d1·N + d3)·(S·L + S(S+1)/2)
    def t_decode_sum(self, N: int, L_i: int, L_o: int) -> float:
        L = bucket_len(L_i, self.bucket)
        d = self.decode
        s = L_o * (d.c2 * N + d.c4) + (d.c1 * N + d.c3) * (L_o * L + L_o * (L_o + 1) / 2.0)
        return max(s, 0.0)

    # -- paper Eq. 1 --
    def t_serve(self, N: int, L_i: int, L_o: int) -> float:
        return self.t_prefill(N, L_i) + self.t_decode_sum(N, L_i, L_o)

    @classmethod
    def fit(cls, prefill_samples, decode_samples, bucket: int = 1
            ) -> Tuple["ServingTimeEstimator", float, float]:
        """prefill_samples: (N, L_i, t); decode_samples: (N, l_cached, t)."""
        pc, prmse = fit_bilinear(prefill_samples)
        dc, drmse = fit_bilinear(decode_samples)
        return cls(pc, dc, bucket=bucket), prmse, drmse


# ---------------------------------------------------------------------------
# calibrated latency profiles
# ---------------------------------------------------------------------------
def a100_llama13b_profile() -> "ServingTimeEstimator":
    """Synthetic calibration matching the paper's Fig. 8/9 scales for
    LLaMA2-13B on A100-80GB under deepspeed-inference (DESIGN.md §2):
    prefill grows ~linearly in N and L (Fig. 8); per-iteration decode is
    dominated by the N·l and l terms (Fig. 9), with a small fixed base —
    which is what makes separate batching win in the paper's Fig. 11.
    Used by the cluster simulator as the *ground-truth* latency model."""
    # prefill: compute-bound, ~0.87s at N=12, L=1024 (Fig. 8)
    prefill = LatencyCoeffs(c1=6.0e-5, c2=1.0e-3, c3=1.0e-4, c4=2.0e-2)
    # decode: c4 = weight-streaming base (N-independent -> batching pays;
    # Fig. 9a shows ~30ms at N=1), c2 = per-request kernel overhead
    # (Fig. 9b slope ~1.7ms/request at l=1024 => c2 + c1·1024), c1 =
    # KV-cache stream; ~45ms at N=12, l=1024
    decode = LatencyCoeffs(c1=8.0e-7, c2=9.0e-4, c3=3.0e-6, c4=2.6e-2)
    return ServingTimeEstimator(prefill, decode)


def a100_llama13b_hf_profile() -> "ServingTimeEstimator":
    """HF-transformers profile: ~2.5-3x slower bases (paper Fig. 10: HF
    latency bases are much larger than DS).  Calibrated so the paper's
    Fig. 11 example reproduces: batching 15 short with 1 long request is
    ~2x slower than serving them separately."""
    # Fig. 11 calibration: 15x10 + 1x1024 together = ~2.5x the cost of
    # serving them as two batches (the big c1 = N·l term is what padding
    # inflates)
    prefill = LatencyCoeffs(c1=2.0e-4, c2=2.0e-3, c3=3.0e-4, c4=5.0e-2)
    decode = LatencyCoeffs(c1=4.0e-6, c2=1.0e-3, c3=6.0e-6, c4=8.0e-3)
    return ServingTimeEstimator(prefill, decode)
