"""Memory-usage estimator (paper §4.3, Eq. 5–9 + Algorithm 2).

Three implementations:
  * AnalyticMemoryEstimator — Eq. 5/9: KV bytes = (L_i + S)·N·Δ ≤ ζ·M_ava,
    for engines with predictable allocators (HF in the paper; our JAX engine
    is exactly predictable, so ζ defaults to 1.0 there).  Mesh-aware: Δ is
    per model-shard (DESIGN.md §8.3).
  * RuleBasedMemoryEstimator — Algorithm 2's profiled rule table for engines
    with opaque allocators (DS in the paper).
  * PagedMemoryEstimator — beyond-paper: the block-pool view of the same
    budget for ``kv_layout="paged"`` engines (``repro.kvcache``), counting
    *free blocks* instead of the ζ·M_ava closed form, so in-flight
    reservations shrink what the batcher may admit.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.request import bucket_len

# Documented ceiling for ``max_batch_size`` when the memory model does not
# bind (e.g. Δ = 0, or a rule table whose last rule always fits): no real
# engine schedules batches beyond this, and callers must never see an
# internal search sentinel leak out as if it were a schedulable size.
MAX_BATCH_SIZE_CAP = 4096


def blocks_for(n_tokens: int, page_tokens: int) -> int:
    """Blocks needed for ``n_tokens`` cache slots (ceil division).

    THE block-rounding rule of the paged KV subsystem: the estimator's
    admission check, the ``repro.kvcache.PageAllocator`` free list, and
    the simulator's admission all share this one definition.
    """
    return -(-max(n_tokens, 0) // page_tokens)


class MemoryEstimator:
    def fits(self, N: int, L_i: int, S: int) -> bool:
        raise NotImplementedError

    def max_batch_size(self, L_i: int, S: int) -> int:
        """Largest N with fits(N, L_i, S) — Eq. 8 for the analytic case.

        Capped at ``MAX_BATCH_SIZE_CAP`` when the constraint never binds.
        """
        if self.fits(MAX_BATCH_SIZE_CAP, L_i, S):
            return MAX_BATCH_SIZE_CAP
        lo, hi = 0, MAX_BATCH_SIZE_CAP
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if self.fits(mid, L_i, S):
                lo = mid
            else:
                hi = mid
        return lo


@dataclasses.dataclass
class AnalyticMemoryEstimator(MemoryEstimator):
    delta_bytes: float          # Δ: KV bytes per token (per model shard)
    m_available: float          # M_ava = M_cap - M_model - M_engine (bytes)
    zeta: float = 1.0           # engine fragmentation factor (Eq. 9)
    bucket: int = 1

    def kv_bytes(self, N: int, L_i: int, S: int) -> float:
        return (bucket_len(L_i, self.bucket) + S) * N * self.delta_bytes  # Eq. 5

    def fits(self, N: int, L_i: int, S: int) -> bool:
        if N <= 0:
            return True
        return self.kv_bytes(N, L_i, S) <= self.zeta * self.m_available  # Eq. 9

    def max_batch_size(self, L_i: int, S: int) -> int:  # Eq. 8 closed form
        denom = self.delta_bytes * (bucket_len(L_i, self.bucket) + S)
        if denom <= 0:
            return MAX_BATCH_SIZE_CAP
        return min(int(self.zeta * self.m_available // denom),
                   MAX_BATCH_SIZE_CAP)


@dataclasses.dataclass
class RuleBasedMemoryEstimator(MemoryEstimator):
    """Paper Algorithm 2: total-token thresholds -> max batch size.

    ``rules`` is a list of (min_total_len_exclusive, max_batch) sorted
    descending; the default is the paper's DS table.
    """

    rules: Sequence[Tuple[int, int]] = ((1024, 12), (512, 22), (0, 28))

    def fits(self, N: int, L_i: int, S: int) -> bool:
        L = L_i + S
        for threshold, max_n in self.rules:
            if L > threshold:
                return N <= max_n
        return N <= self.rules[-1][1]


@dataclasses.dataclass
class PagedMemoryEstimator(MemoryEstimator):
    """Block-pool memory model for ``kv_layout="paged"`` (``repro.kvcache``).

    The same ζ·M_ava byte budget as the analytic model, viewed as a pool of
    fixed-size token blocks: a request scheduled with batch input length
    L_i and slice S occupies ⌈(L_i + S)/pg⌉ blocks (Eq. 5 rounded up to
    block granularity).  Unlike the closed form, ``max_batch_size`` counts
    *currently free* blocks.

    ``reserve_batch`` / ``release_blocks`` track in-flight slices for
    runtimes that overlap batch execution on one machine.  The current
    cluster runtimes serve one batch per worker at a time (RealCluster
    additionally enforces the envelope with a real per-worker
    ``repro.kvcache.PageAllocator``), so they admit via ``fits`` alone and
    ``reserved_blocks`` stays 0 there — any future overlapped-execution
    runtime must reserve around each in-flight slice or it will
    over-admit.

    Retention (``kv_retain``, the persistent-paged StaticEngine path):
    with ``kv_retain="request"`` the real backend keeps each in-flight
    request's prefix pages resident across slices, and ``retained_blocks``
    gauges them.  The Eq. 5–9 feasibility math deliberately still counts
    retained pages as *free*: retained prefixes are reclaimable on demand
    (the engine's evict-on-pressure path falls back to classic §3.3
    re-prefill), so a scheduled batch can always claim its envelope — the
    no-OOM guarantee is exactly the slice-scoped one, while the gauge
    makes the retention state observable (``/healthz``, benchmarks).
    """

    delta_bytes: float          # Δ: KV bytes per token (per model shard)
    m_available: float          # M_ava = M_cap - M_model - M_engine (bytes)
    page_tokens: int = 16       # block size in cache slots
    zeta: float = 1.0           # engine fragmentation factor (Eq. 9)
    bucket: int = 1
    kv_retain: str = "slice"    # "slice" | "request" (see RealBackend)

    def __post_init__(self):
        if self.kv_retain not in ("slice", "request"):
            raise ValueError(f"unknown kv_retain {self.kv_retain!r} "
                             f"(expected 'slice' or 'request')")
        bytes_per_block = self.page_tokens * self.delta_bytes
        self.total_blocks = (int(self.zeta * self.m_available
                                 // bytes_per_block)
                             if bytes_per_block > 0 else 0)
        self.reserved_blocks = 0
        #: observability gauge (never admission): blocks currently held by
        #: retained/in-flight requests on the real engines
        self.retained_blocks = 0

    # ------------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return self.total_blocks - self.reserved_blocks

    def blocks_per_request(self, L_i: int, S: int) -> int:
        return blocks_for(bucket_len(L_i, self.bucket) + S, self.page_tokens)

    def fits(self, N: int, L_i: int, S: int) -> bool:
        if N <= 0:
            return True
        if self.total_blocks == 0:  # Δ = 0: memory model cannot bind
            return N <= MAX_BATCH_SIZE_CAP
        return N * self.blocks_per_request(L_i, S) <= self.free_blocks

    def max_batch_size(self, L_i: int, S: int) -> int:
        """Counts free blocks — NOT the ζ·M_ava closed form."""
        if self.total_blocks == 0:
            return MAX_BATCH_SIZE_CAP
        return min(self.free_blocks // self.blocks_per_request(L_i, S),
                   MAX_BATCH_SIZE_CAP)

    def fits_envelope(self, prefix_blocks: int) -> bool:
        """Envelope-exact Eq. 5–9: admit a batch charged the SUM of its
        members' per-request envelopes Σ_j ⌈(L_j + S)/pg⌉, not the
        batch-max ``N · ⌈(L_max + S)/pg⌉`` that ``fits`` rounds up to.
        Since Σ_j blocks_j ≤ N · blocks_max always, this bound is at
        least as permissive as ``fits`` for the same batch — mixed-length
        batches stop paying for the longest member's envelope N times.

        ``prefix_blocks`` is that sum (the envelope DP supplies it as a
        prefix-sum difference, keeping each transition O(1)).  Monotone:
        widening a sorted batch only grows the sum, so a DP may break on
        the first failure.  When Δ = 0 the pool is unbounded and nothing
        binds — callers must cap N at ``MAX_BATCH_SIZE_CAP`` themselves
        (``fits`` bounds N directly; a block sum cannot).
        """
        if prefix_blocks <= 0:
            return True
        if self.total_blocks == 0:  # Δ = 0: memory model cannot bind
            return True
        return prefix_blocks <= self.free_blocks

    # ------------------------------------------------------------------
    # in-flight accounting (cluster runtimes)
    # ------------------------------------------------------------------
    def reserve_batch(self, N: int, L_i: int, S: int) -> int:
        """Reserve a scheduled batch's blocks; returns the count to release."""
        blocks = N * self.blocks_per_request(L_i, S)
        self.reserved_blocks += blocks
        return blocks

    def release_blocks(self, blocks: int) -> None:
        self.reserved_blocks = max(0, self.reserved_blocks - blocks)


def model_kv_delta(n_layers: int, n_kv_heads: int, head_dim: int,
                   bytes_per_el: int = 2, n_model_shards: int = 1) -> float:
    """Δ for a dense GQA transformer (2 = K and V)."""
    return 2.0 * n_layers * n_kv_heads * head_dim * bytes_per_el / max(
        min(n_model_shards, n_kv_heads), 1)


# LLaMA2-13B: 40 layers, 40 heads, 128 head_dim, fp16
LLAMA2_13B_DELTA = model_kv_delta(40, 40, 128, 2)
# A100-80GB serving LLaMA2-13B (26GB weights fp16, ~4GB engine overhead)
A100_80GB_AVAILABLE = 80e9 - 26e9 - 4e9
