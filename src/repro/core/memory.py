"""Memory-usage estimator (paper §4.3, Eq. 5–9 + Algorithm 2).

Two implementations, as in the paper:
  * AnalyticMemoryEstimator — Eq. 5/9: KV bytes = (L_i + S)·N·Δ ≤ ζ·M_ava,
    for engines with predictable allocators (HF in the paper; our JAX engine
    is exactly predictable, so ζ defaults to 1.0 there).  Mesh-aware: Δ is
    per model-shard (DESIGN.md §8.3).
  * RuleBasedMemoryEstimator — Algorithm 2's profiled rule table for engines
    with opaque allocators (DS in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.request import bucket_len


class MemoryEstimator:
    def fits(self, N: int, L_i: int, S: int) -> bool:
        raise NotImplementedError

    def max_batch_size(self, L_i: int, S: int) -> int:
        """Largest N with fits(N, L_i, S) — Eq. 8 for the analytic case."""
        lo, hi = 0, 1
        while self.fits(hi, L_i, S):
            hi *= 2
            if hi > 1 << 20:
                return hi
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if self.fits(mid, L_i, S):
                lo = mid
            else:
                hi = mid
        return lo


@dataclasses.dataclass
class AnalyticMemoryEstimator(MemoryEstimator):
    delta_bytes: float          # Δ: KV bytes per token (per model shard)
    m_available: float          # M_ava = M_cap - M_model - M_engine (bytes)
    zeta: float = 1.0           # engine fragmentation factor (Eq. 9)
    bucket: int = 1

    def kv_bytes(self, N: int, L_i: int, S: int) -> float:
        return (bucket_len(L_i, self.bucket) + S) * N * self.delta_bytes  # Eq. 5

    def fits(self, N: int, L_i: int, S: int) -> bool:
        if N <= 0:
            return True
        return self.kv_bytes(N, L_i, S) <= self.zeta * self.m_available  # Eq. 9

    def max_batch_size(self, L_i: int, S: int) -> int:  # Eq. 8 closed form
        denom = self.delta_bytes * (bucket_len(L_i, self.bucket) + S)
        if denom <= 0:
            return 1 << 20
        return int(self.zeta * self.m_available // denom)


@dataclasses.dataclass
class RuleBasedMemoryEstimator(MemoryEstimator):
    """Paper Algorithm 2: total-token thresholds -> max batch size.

    ``rules`` is a list of (min_total_len_exclusive, max_batch) sorted
    descending; the default is the paper's DS table.
    """

    rules: Sequence[Tuple[int, int]] = ((1024, 12), (512, 22), (0, 28))

    def fits(self, N: int, L_i: int, S: int) -> bool:
        L = L_i + S
        for threshold, max_n in self.rules:
            if L > threshold:
                return N <= max_n
        return N <= self.rules[-1][1]


def model_kv_delta(n_layers: int, n_kv_heads: int, head_dim: int,
                   bytes_per_el: int = 2, n_model_shards: int = 1) -> float:
    """Δ for a dense GQA transformer (2 = K and V)."""
    return 2.0 * n_layers * n_kv_heads * head_dim * bytes_per_el / max(
        min(n_model_shards, n_kv_heads), 1)


# LLaMA2-13B: 40 layers, 40 heads, 128 head_dim, fp16
LLAMA2_13B_DELTA = model_kv_delta(40, 40, 128, 2)
# A100-80GB serving LLaMA2-13B (26GB weights fp16, ~4GB engine overhead)
A100_80GB_AVAILABLE = 80e9 - 26e9 - 4e9
