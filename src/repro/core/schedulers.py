"""Scheduling strategies: the paper's SCLS and every baseline/ablation.

Strategies are declarative configs consumed by the cluster runtime
(``repro.cluster.simulator`` drives the same logic in virtual time that
``repro.launch.serve`` drives against real JAX engines):

  SLS  — per-request round-robin offload; workers run FCFS static batches of
         fixed size with iteration limit = max_gen (paper baseline).
  ILS  — per-request round-robin; continuous batching with a conservative
         parallelism cap (DeepSpeed-FastGen-like baseline).
  SO   — SLS + generation slicing (iteration limit = S, reschedule).
  PM   — SO + sorted contiguous batching capped at the fixed batch size,
         fetched centrally every Γ, round-robin offload.
  AB   — PM with the cap lifted: full DP adaptive batching (Algorithm 1).
  LB   — AB + max-min offloading (§4.5).
  SCLS — LB + adaptive schedule interval (§4.6, Eq. 12).

Beyond-paper strategies:

  SCLS-CB   — slice leases on top of continuous batching (§7 Discussion).
  SCLS-PRED — SCLS + the ``repro.predict`` generation-length subsystem
         (cf. §6 Related Work: S³/PiA and proxy-model predictors).  At each
         central tick, every pooled request gets a calibrated remaining-
         length cap from an online predictor (histogram/EWMA, JAX proxy
         MLP, or ground truth).  Requests with cap ≥ S are scheduled
         exactly like SCLS; requests predicted to finish within a slice
         are bucketed by cap and served with exact per-batch slice lengths
         (``core.batcher.bucketed_pred_batch``), eliminating most invalid
         tokens and letting memory-bound workers pack tighter batches.
         Calibrated caps interact with the slice length S as a *ceiling*:
         a cap never stretches a serving round beyond S, and a request
         that outlives its cap is rescheduled like any unfinished slice —
         so a bad predictor degrades SCLS-PRED to SCLS, never breaks it.
  ORACLE — SCLS-PRED with a perfect predictor: the analysis upper bound
         (the price of length-blindness is SCLS's gap to it).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class StrategyConfig:
    name: str
    mode: str  # "perreq" | "central" | "continuous"
    slice_len: int
    max_gen: int = 1024
    fixed_batch_size: Optional[int] = None  # worker-local FCFS batch size
    use_dp: bool = False
    dp_cap: Optional[int] = None  # PM: DP with batch-size cap
    offload: str = "rr"  # "rr" | "maxmin"
    adaptive_interval: bool = False
    gamma: float = 3.0  # Γ: minimal schedule interval (s)
    lam: float = 0.5  # λ in Eq. 12
    # ILS conservative memory management
    max_parallel: int = 12
    max_cached_tokens: Optional[int] = None
    # KV-cache layout on the workers (repro.kvcache): "dense" reserves a
    # contiguous worst-case region per engine slot; "paged" allocates
    # fixed-size token blocks against the (L_i + S) slice envelope, so
    # parallelism is bounded by real free memory instead of a slot count.
    # Continuous-mode runtimes require a PagedMemoryEstimator when "paged".
    kv_layout: str = "dense"
    # Algorithm-1 no-OOM bound (core.batcher.PACKING_MODES): "batch-max"
    # charges every member the longest member's (L_i + S) envelope (the
    # paper's O(1) closed form — the default, pinned by the goldens);
    # "envelope" charges each member its own blocks_for(L_j + S) via
    # prefix sums — at least as permissive, requires kv_layout="paged"
    # (the bound is exact only against a block pool).
    packing: str = "batch-max"
    # SCLS-PRED / ORACLE (mode "pred"): generation-length prediction
    predictor: Optional[str] = None   # "histogram" | "proxy" | "perfect"
    coverage: float = 0.7             # calibration target quantile
    bucket_phi: float = 2.0           # geometric short-bucket ratio
    min_pred_slice: int = 16          # floor for predicted slice lengths

    @property
    def slices(self) -> bool:
        return self.slice_len < self.max_gen


def make_strategy(name: str, slice_len: int = 128, max_gen: int = 1024,
                  fixed_batch_size: int = 12, gamma: float = 3.0,
                  lam: float = 0.5, max_parallel: int = 12,
                  predictor: str = "histogram", coverage: float = 0.7,
                  bucket_phi: float = 2.0,
                  kv_layout: str = "dense",
                  packing: str = "batch-max") -> StrategyConfig:
    name = name.lower()
    if kv_layout not in ("dense", "paged"):
        raise ValueError(f"unknown kv_layout {kv_layout!r}")
    if packing not in ("batch-max", "envelope"):
        raise ValueError(f"unknown packing {packing!r} "
                         f"(expected 'batch-max' or 'envelope')")
    if packing == "envelope" and kv_layout != "paged":
        raise ValueError(
            "packing='envelope' charges per-request block envelopes, "
            "which only a paged block pool can account exactly; set "
            "kv_layout='paged' (or keep the default 'batch-max' bound)")
    base = dict(slice_len=slice_len, max_gen=max_gen, gamma=gamma, lam=lam,
                kv_layout=kv_layout, packing=packing)
    if name == "sls":
        return StrategyConfig("SLS", "perreq", slice_len=max_gen, max_gen=max_gen,
                              fixed_batch_size=fixed_batch_size, gamma=gamma,
                              lam=lam, kv_layout=kv_layout, packing=packing)
    if name == "ils":
        return StrategyConfig("ILS", "continuous", slice_len=max_gen, max_gen=max_gen,
                              max_parallel=max_parallel, gamma=gamma, lam=lam,
                              kv_layout=kv_layout, packing=packing)
    if name == "so":
        return StrategyConfig("SO", "perreq", fixed_batch_size=fixed_batch_size, **base)
    if name == "pm":
        return StrategyConfig("PM", "central", use_dp=True, dp_cap=fixed_batch_size,
                              offload="rr", **base)
    if name == "ab":
        return StrategyConfig("AB", "central", use_dp=True, offload="rr", **base)
    if name == "lb":
        return StrategyConfig("LB", "central", use_dp=True, offload="maxmin", **base)
    if name == "scls":
        return StrategyConfig("SCLS", "central", use_dp=True, offload="maxmin",
                              adaptive_interval=True, **base)
    # predicted-slice floor: scales with S so small-slice setups (e.g. the
    # reduced serve demo at S=8) still exercise the short buckets instead
    # of flooring every cap into the long group.  The floor exists to
    # amortize the reschedule cost of *under*-predictions, so perfect
    # predictions get none — ORACLE serves exact slices (zero overshoot)
    min_pred_slice = 1 if predictor == "perfect" else max(
        1, min(16, slice_len // 8))
    if name == "scls-pred":
        # SCLS + online length prediction (repro.predict): bucket by
        # calibrated predicted remaining length, exact slice lengths for
        # requests predicted to finish within a slice
        return StrategyConfig("SCLS-PRED", "pred", use_dp=True,
                              offload="maxmin", adaptive_interval=True,
                              predictor=predictor, coverage=coverage,
                              bucket_phi=bucket_phi,
                              min_pred_slice=min_pred_slice, **base)
    if name == "oracle":
        # analysis upper bound (cf. PiA / S^3, paper §6 Related Work):
        # SCLS-PRED with a perfect generation-length predictor — requests
        # are bucketed by exactly-known remaining length, short requests
        # finish in one exact slice with zero overshoot.  SCLS's gap to
        # this bound is the price of length-blindness.
        return StrategyConfig("ORACLE", "pred", use_dp=True,
                              offload="maxmin", adaptive_interval=True,
                              predictor="perfect", coverage=coverage,
                              bucket_phi=bucket_phi, min_pred_slice=1, **base)
    if name == "scls-cb":
        # beyond-paper (§7 Discussion): slice-level scheduling ON TOP OF
        # continuous batching — requests get S-token *leases* on a worker,
        # join/exit at iteration boundaries under an exact token budget
        # (slices make memory predictable, so no conservative cap), and
        # leases are placed max-min by estimated slice time.
        return StrategyConfig("SCLS-CB", "cont_scls", use_dp=False,
                              offload="maxmin", adaptive_interval=True,
                              max_parallel=1 << 30, **base)
    raise ValueError(f"unknown strategy {name!r}")


ALL_STRATEGIES = ("sls", "ils", "so", "pm", "ab", "lb", "scls", "scls-cb",
                  "scls-pred", "oracle")
