"""Serving time-oriented batching (paper §4.4, Algorithm 1).

Sort requests ascending by effective input length; a dynamic program over
the sorted order partitions them into contiguous batches minimizing total
estimated serving time, subject to the no-OOM constraint.  Because requests
are sorted, request i's input length is the batch input length for any
batch ending at i, so each DP transition is O(1) via the estimator's closed
form.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.estimator import ServingTimeEstimator
from repro.core.memory import MemoryEstimator
from repro.core.request import Batch, Request, bucket_len


def dp_batch(requests: Sequence[Request], slice_len: int,
             est: ServingTimeEstimator, mem: MemoryEstimator,
             max_batch_size: Optional[int] = None) -> List[Batch]:
    """Algorithm 1.  ``max_batch_size`` caps N (None = unbounded, the full
    adaptive batcher; the PM ablation passes the engine's fixed size)."""
    if not requests:
        return []
    reqs = sorted(requests, key=lambda r: r.effective_input_len)
    n = len(reqs)
    INF = float("inf")
    T = [0.0] + [INF] * n  # T[i]: min total time for first i requests
    P = [0] * (n + 1)      # split positions

    lens = [r.effective_input_len for r in reqs]
    for i in range(1, n + 1):
        L_i = lens[i - 1]
        # request i as its own batch
        T[i] = T[i - 1] + est.t_serve(1, L_i, slice_len)
        P[i] = i - 1
        # widen the batch over preceding requests j..i
        j = i - 1
        while j > 0:
            N = i - j + 1
            if max_batch_size is not None and N > max_batch_size:
                break
            if not mem.fits(N, L_i, slice_len):
                break
            t = T[j - 1] + est.t_serve(N, L_i, slice_len)
            if t < T[i]:
                T[i] = t
                P[i] = j - 1
            j -= 1

    batches: List[Batch] = []
    i = n
    while i > 0:
        p = P[i]
        group = reqs[p:i]
        L = group[-1].effective_input_len  # sorted: last has the max
        b = Batch(requests=list(group), input_len=bucket_len(L, est.bucket),
                  slice_len=slice_len)
        b.est_time = est.t_serve(b.size, L, slice_len)
        batches.append(b)
        i = p
    batches.reverse()
    return batches


def fcfs_batch(requests: Sequence[Request], batch_size: int, slice_len: int,
               est: Optional[ServingTimeEstimator] = None) -> List[Batch]:
    """SLS / SO baseline batching: FCFS order, fixed batch size."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    batches = []
    for i in range(0, len(reqs), batch_size):
        group = reqs[i:i + batch_size]
        L = max(r.effective_input_len for r in group)
        b = Batch(requests=group, input_len=L, slice_len=slice_len)
        if est is not None:
            b.est_time = est.t_serve(b.size, L, slice_len)
        batches.append(b)
    return batches


def total_time(batches: Sequence[Batch]) -> float:
    return sum(b.est_time for b in batches)
