"""Serving time-oriented batching (paper §4.4, Algorithm 1).

Sort requests ascending by effective input length; a dynamic program over
the sorted order partitions them into contiguous batches minimizing total
estimated serving time, subject to the no-OOM constraint.  Because requests
are sorted, request i's input length is the batch input length for any
batch ending at i, so each DP transition is O(1) via the estimator's closed
form.

``bucketed_pred_batch`` extends Algorithm 1 with generation-length
predictions (the ``scls-pred``/ORACLE path): requests predicted to outlive
a slice are DP-batched exactly like SCLS, while requests predicted to
finish within one are grouped into geometric remaining-length buckets and
served with exact per-batch slice lengths.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.core.estimator import ServingTimeEstimator
from repro.core.memory import (MAX_BATCH_SIZE_CAP, MemoryEstimator,
                               PagedMemoryEstimator)
from repro.core.request import Batch, Request, bucket_len

#: no-OOM bounds the DP may pack against: "batch-max" is the paper's
#: Eq. 5–9 check ``fits(N, L_max, S)`` (every member charged the longest
#: member's envelope); "envelope" charges each member its own
#: ``blocks_for(L_j + S)`` via ``PagedMemoryEstimator.fits_envelope`` —
#: at least as permissive, exact on the paged engines which reserve
#: per-request envelopes anyway (``StaticEngine.serve_batch_paged``)
PACKING_MODES = ("batch-max", "envelope")


def _check_packing(packing: str, mem: MemoryEstimator) -> None:
    if packing not in PACKING_MODES:
        raise ValueError(f"unknown packing {packing!r} "
                         f"(expected one of {PACKING_MODES})")
    if packing == "envelope" and not isinstance(mem, PagedMemoryEstimator):
        raise ValueError(
            f"packing='envelope' charges per-request block envelopes, "
            f"which needs a PagedMemoryEstimator (kv_layout='paged'); "
            f"got {type(mem).__name__}")


def batch_fits(b: Batch, mem: MemoryEstimator,
               packing: str = "batch-max") -> bool:
    """Eq. 5–9 feasibility of an already-composed batch under either
    packing bound — the recheck used after ``bucketed_pred_batch``
    rewrites slice lengths, and by tests/audit."""
    S = int(b.slice_len)
    if packing == "envelope":
        total = sum(mem.blocks_per_request(r.effective_input_len, S)
                    for r in b.requests)
        return mem.fits_envelope(total)
    return mem.fits(b.size, int(b.input_len), S)


def dp_batch(requests: Sequence[Request], slice_len: int,
             est: ServingTimeEstimator, mem: MemoryEstimator,
             max_batch_size: Optional[int] = None,
             packing: str = "batch-max") -> List[Batch]:
    """Algorithm 1.  ``max_batch_size`` caps N (None = unbounded, the full
    adaptive batcher; the PM ablation passes the engine's fixed size).

    ``packing`` picks the no-OOM bound (``PACKING_MODES``): the default
    "batch-max" transition is the paper's O(1) closed form; "envelope"
    keeps O(1) transitions by prefix-summing the sorted requests'
    per-request block envelopes, so a batch over ``reqs[j-1:i]`` is
    charged exactly ``pre[i] - pre[j-1]`` blocks.
    """
    _check_packing(packing, mem)
    if not requests:
        return []
    reqs = sorted(requests, key=lambda r: r.effective_input_len)
    n = len(reqs)
    INF = float("inf")
    T = [0.0] + [INF] * n  # T[i]: min total time for first i requests
    P = [0] * (n + 1)      # split positions

    lens = [r.effective_input_len for r in reqs]
    pre = [0] * (n + 1)  # envelope mode: prefix sums of per-request blocks
    if packing == "envelope":
        for idx, L in enumerate(lens):
            pre[idx + 1] = pre[idx] + mem.blocks_per_request(L, slice_len)
    for i in range(1, n + 1):
        L_i = lens[i - 1]
        # request i as its own batch
        T[i] = T[i - 1] + est.t_serve(1, L_i, slice_len)
        P[i] = i - 1
        # widen the batch over preceding requests j..i
        j = i - 1
        while j > 0:
            N = i - j + 1
            if max_batch_size is not None and N > max_batch_size:
                break
            if packing == "envelope":
                # Σ blocks over reqs[j-1:i] grows as j widens left and
                # fits_envelope is monotone in it, so breaking on the
                # first failure is exact; fits_envelope cannot bound N
                # when the pool is unbounded (Δ = 0), so cap N here
                if N > MAX_BATCH_SIZE_CAP:
                    break
                if not mem.fits_envelope(pre[i] - pre[j - 1]):
                    break
            elif not mem.fits(N, L_i, slice_len):
                break
            t = T[j - 1] + est.t_serve(N, L_i, slice_len)
            if t < T[i]:
                T[i] = t
                P[i] = j - 1
            j -= 1

    batches: List[Batch] = []
    i = n
    while i > 0:
        p = P[i]
        group = reqs[p:i]
        L = group[-1].effective_input_len  # sorted: last has the max
        b = Batch(requests=list(group), input_len=bucket_len(L, est.bucket),
                  slice_len=slice_len)
        b.est_time = est.t_serve(b.size, L, slice_len)
        batches.append(b)
        i = p
    batches.reverse()
    return batches


def bucketed_pred_batch(requests: Sequence[Request], caps: Dict[int, int],
                        slice_len: int, est: ServingTimeEstimator,
                        mem: MemoryEstimator, phi: float = 2.0,
                        min_slice: int = 16,
                        packing: str = "batch-max") -> List[Batch]:
    """Length-prediction-aware batching (``scls-pred`` / refactored ORACLE).

    ``caps[rid]`` is the calibrated remaining-length cap for each request.
    Requests with cap >= ``slice_len`` form one "long" group scheduled
    exactly like SCLS (slice = ``slice_len``): under-predictions therefore
    degrade to plain slice-level scheduling, never to incorrectness.
    Requests predicted to finish within a slice are bucketed by cap with
    geometric ratio ``phi`` (bounding the within-batch length spread, hence
    the invalid tokens, by a factor of ``phi``), DP-batched within each
    bucket, and served with slice length = the batch's largest cap — so a
    correctly-predicted request completes in this round with no overshoot
    beyond the ``min_slice`` floor (perfect predictions use floor 1).

    ``min_slice`` floors the short-bucket slice lengths: an under-predicted
    request costs a full reschedule (another prefill and another wait for a
    tick), so serving micro-slices on the word of an imperfect predictor is
    a bad trade — a few invalid tokens are far cheaper.
    """
    if phi <= 1.0:
        raise ValueError(f"bucket ratio phi must be > 1, got {phi}")
    if not requests:
        return []
    min_slice = max(1, min(min_slice, slice_len))
    log_phi = math.log(phi)
    groups: Dict[int, List[Request]] = {}
    eff: Dict[int, int] = {}
    for r in requests:
        c = max(int(caps[r.rid]), min_slice)
        eff[r.rid] = c
        if c >= slice_len:
            key = -1
        else:
            key = int(math.ceil(math.log(c) / log_phi))
        groups.setdefault(key, []).append(r)
    batches: List[Batch] = []
    for key, group in sorted(groups.items()):
        if key == -1:
            batches.extend(dp_batch(group, slice_len, est, mem,
                                    packing=packing))
            continue
        bucket_cap = min(slice_len, max(eff[r.rid] for r in group))
        for b in dp_batch(group, bucket_cap, est, mem, packing=packing):
            b.slice_len = min(slice_len, max(eff[r.rid] for r in b.requests))
            b.est_time = est.t_serve(b.size, b.input_len, b.slice_len)
            # the DP admitted this batch under Eq. 5–9 at slice =
            # bucket_cap ≥ b.slice_len; every shipped estimator's bound is
            # monotone in S, so the shrunk batch still fits — but that was
            # previously assumed, not checked.  Recompute the bound against
            # the FINAL slice length so a non-monotone estimator (a future
            # rule table, say) fails loudly here instead of OOMing a worker.
            if not batch_fits(b, mem, packing):
                raise RuntimeError(
                    f"bucketed_pred_batch: batch of {b.size} no longer "
                    f"satisfies the Eq. 5–9 bound after shrinking slice "
                    f"{bucket_cap} -> {b.slice_len} (non-monotone memory "
                    f"estimator {type(mem).__name__}?)")
            batches.append(b)
    return batches


def batch_audit_fields(b: Batch, mem: MemoryEstimator) -> Dict[str, object]:
    """Decision-audit record for one Algorithm-1 batch (``repro.obs``).

    Reconstructs the inputs the DP transition saw when it closed this
    batch: the member rids, the bucketed batch input length, the chosen
    slice length, the Eq. 1–2 estimated serving time already priced on
    the batch, and the Eq. 5–9 memory bound ``max_batch_size(L_i, S)``
    the no-OOM constraint compared ``N`` against.  Pure read — safe to
    call from observability hooks on a live scheduler.

    On a block-pool estimator the record additionally carries the
    envelope-exact view of the same bound: ``envelope_blocks`` (the sum
    of the members' per-request ``blocks_for(L_j + S)`` charges — what
    the paged engine actually reserves) and ``envelope_fits`` (its
    ``fits_envelope`` verdict), regardless of which packing mode composed
    the batch — so audits of batch-max runs show the blocks the tighter
    bound would have freed.
    """
    fields: Dict[str, object] = dict(
        rids=sorted(r.rid for r in b.requests),
        slice_len=int(b.slice_len),
        input_len=int(b.input_len),
        est_time=float(b.est_time),
        mem_bound=int(mem.max_batch_size(int(b.input_len),
                                         int(b.slice_len))))
    if isinstance(mem, PagedMemoryEstimator):
        env = sum(mem.blocks_per_request(r.effective_input_len,
                                         int(b.slice_len))
                  for r in b.requests)
        fields["envelope_blocks"] = int(env)
        fields["envelope_fits"] = bool(mem.fits_envelope(env))
    return fields


def fcfs_batch(requests: Sequence[Request], batch_size: int, slice_len: int,
               est: Optional[ServingTimeEstimator] = None) -> List[Batch]:
    """SLS / SO baseline batching: FCFS order, fixed batch size."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    batches = []
    for i in range(0, len(reqs), batch_size):
        group = reqs[i:i + batch_size]
        L = max(r.effective_input_len for r in group)
        b = Batch(requests=group, input_len=L, slice_len=slice_len)
        if est is not None:
            b.est_time = est.t_serve(b.size, L, slice_len)
        batches.append(b)
    return batches


def total_time(batches: Sequence[Batch]) -> float:
    return sum(b.est_time for b in batches)
