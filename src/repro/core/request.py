"""Request / Batch types shared by schedulers, engines, and the simulator."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One user request.

    ``gen_len`` is the request's *true* generation length (number of decode
    iterations until EOS).  It is ground truth for the workload generator /
    engine and is NEVER read by any scheduler — schedulers only observe
    ``input_len``, ``generated`` and completion events, exactly as in the
    paper.  ``gen_len=None`` (online submissions through
    ``repro.serving``) means the length is unknown in advance: the real
    backend decodes until the model's own EOS, the sim backend until
    ``max_gen``.
    """

    rid: int
    arrival: float
    input_len: int
    gen_len: Optional[int]
    max_gen: int = 1024
    prompt: Optional[np.ndarray] = None  # actual tokens (real-execution mode)
    #: absolute completion deadline in core time (``arrival + slo``), set
    #: by the online serving API's SLO-aware admission; None = best-effort.
    #: Schedulers never read it — it exists for admission decisions (made
    #: before submission) and the SLO-attainment metric.
    deadline: Optional[float] = None
    #: multi-turn session this request is a turn of (``repro.serving``
    #: ``Session`` / HTTP chat): on completion the real retain-mode
    #: backend anchors its prefix pages for the next turn's prefix join
    #: instead of freeing them.  Schedulers never read it.
    session_id: Optional[int] = None

    # --- scheduling state ---
    generated: int = 0
    done: bool = False
    cancelled: bool = False  # terminal via SliceServer.cancel(), not EOS
    n_schedules: int = 0
    finish_time: Optional[float] = None
    first_token_time: Optional[float] = None
    # accounting (paper Figs. 13/16/19)
    pad_tokens: int = 0
    invalid_tokens: int = 0
    output_tokens: Optional[list] = None  # generated token ids (real mode)

    @property
    def effective_input_len(self) -> int:
        """Input length at (re)schedule time: prompt + already-generated."""
        return self.input_len + self.generated

    @property
    def remaining_gen(self) -> int:
        cap = (self.max_gen if self.gen_len is None
               else min(self.gen_len, self.max_gen))
        return cap - self.generated

    def response_time(self) -> float:
        assert self.finish_time is not None
        return self.finish_time - self.arrival


@dataclasses.dataclass
class Batch:
    """A scheduled unit of work: requests padded to ``input_len`` and served
    for at most ``slice_len`` iterations (SCLS) or ``max_gen`` (SLS)."""

    requests: List[Request]
    input_len: int  # batch input length (max effective input len, bucketed)
    slice_len: int  # iteration limit for this serving round
    est_time: float = 0.0

    @property
    def size(self) -> int:
        return len(self.requests)


def bucket_len(L: int, bucket: int) -> int:
    """TPU adaptation: round L up to a multiple of ``bucket`` (DESIGN.md §8)."""
    if bucket <= 1:
        return L
    return ((L + bucket - 1) // bucket) * bucket
