"""``repro.obs`` — observability for the slice-level serving stack.

Three pillars (see ``docs/observability.md``):

  1. **Tracing** (:mod:`repro.obs.trace`) — Chrome trace-event spans /
     instants / counter tracks, exported as Perfetto-loadable JSON;
  2. **Metrics** (:mod:`repro.obs.metrics`) — dependency-free
     Prometheus-style registry served at ``GET /metrics``;
  3. **Decision audit** (:mod:`repro.obs.audit`) — ring-buffered
     structured records of every admission / batching / offload decision,
     queryable at ``GET /debug/decisions``.

:class:`repro.obs.Observability` bundles all three and implements the
scheduler hooks; ``Observability.off()`` is the shared disabled bundle.
"""
from repro.obs.audit import DecisionLog
from repro.obs.hub import (OBS_OFF, Observability, ServingInstruments,
                           decisions_path_for)
from repro.obs.metrics import (DEFAULT_TIME_BUCKETS, DEFAULT_TOKEN_BUCKETS,
                               Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.trace import (NULL_TRACER, PID_REQUESTS, PID_SCHED,
                             TID_CONTROL, Tracer, worker_tid)

__all__ = [
    "DecisionLog",
    "Observability",
    "ServingInstruments",
    "OBS_OFF",
    "decisions_path_for",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "DEFAULT_TIME_BUCKETS",
    "DEFAULT_TOKEN_BUCKETS",
    "Tracer",
    "NULL_TRACER",
    "PID_SCHED",
    "PID_REQUESTS",
    "TID_CONTROL",
    "worker_tid",
]
