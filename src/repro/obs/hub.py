"""Observability hub: wiring the three ``repro.obs`` pillars into the
serving stack.

:class:`Observability` bundles a :class:`~repro.obs.trace.Tracer`, a
:class:`~repro.obs.metrics.MetricsRegistry` (plus the pre-declared
serving instruments), and a :class:`~repro.obs.audit.DecisionLog`, and
implements every scheduler hook as a method — ``SchedulerCore`` only ever
does ``if self.obs.enabled: self.obs.on_dispatch(...)``, so the hot path
costs one attribute read and a bool test when observability is off, and
all emission logic lives here, not in the scheduler.

The cardinal rule is **zero scheduling perturbation**: every hook reads
scheduler state, none mutates it, and nothing here draws randomness —
the golden dispatch logs are asserted bit-exact with full observability
enabled (``tests/test_obs.py``).

Construction:

  * ``Observability.off()`` — the shared disabled instance (the default
    for a bare ``SchedulerCore``; offline paper replays pay nothing);
  * ``Observability.standard(trace=...)`` — metrics + decision audit
    always, Chrome tracing when ``trace=True`` (what ``ServingConfig``
    builds for servers).

Metric catalog (all ``scls_`` namespaced; catalog with units in
``docs/observability.md``):

  histograms  ``scls_ttft_seconds``, ``scls_response_seconds``,
              ``scls_slice_seconds``
  counters    ``scls_slices_dispatched_total``,
              ``scls_requests_total{outcome}``,
              ``scls_admission_total{action,reason}``,
              ``scls_reprefill_tokens_total``,
              ``scls_prefix_hit_tokens_total``
  gauges      ``scls_queue_depth``, ``scls_in_flight_slices``,
              ``scls_kv_free_pages``, ``scls_kv_retained_blocks``,
              ``scls_kv_evictions``, ``scls_kv_shared_blocks``
"""
from __future__ import annotations

import json
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from repro.obs.audit import DecisionLog
from repro.obs.metrics import (DEFAULT_TIME_BUCKETS, DEFAULT_TOKEN_BUCKETS,
                               MetricsRegistry)
from repro.obs.trace import NULL_TRACER, Tracer

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.request import Batch, Request
    from repro.serving.admission import AdmissionDecision
    from repro.serving.core import SchedulerCore

__all__ = ["Observability", "ServingInstruments", "OBS_OFF",
           "decisions_path_for"]


def decisions_path_for(trace_path: str) -> str:
    """Sibling path of the decision-audit dump for ``--trace-out PATH``
    (``trace.json`` → ``trace.decisions.json``)."""
    if trace_path.endswith(".json"):
        return trace_path[:-len(".json")] + ".decisions.json"
    return trace_path + ".decisions.json"


class ServingInstruments:
    """The serving stack's pre-declared metrics (see module docstring)."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.ttft = registry.histogram(
            "scls_ttft_seconds",
            "Time to first token in core seconds (slice-granular)",
            buckets=DEFAULT_TIME_BUCKETS)
        self.response = registry.histogram(
            "scls_response_seconds",
            "End-to-end response latency in core seconds",
            buckets=DEFAULT_TIME_BUCKETS)
        self.slice_time = registry.histogram(
            "scls_slice_seconds",
            "Execution time of one dispatched slice in core seconds",
            buckets=DEFAULT_TIME_BUCKETS)
        self.reprefill_hist = registry.histogram(
            "scls_slice_reprefill_tokens",
            "Re-prefilled tokens per dispatched slice (paper section 3.3)",
            buckets=DEFAULT_TOKEN_BUCKETS)
        self.slices = registry.counter(
            "scls_slices_dispatched_total",
            "Dispatched slices (static batches and continuous spans)")
        self.requests = registry.counter(
            "scls_requests_total",
            "Finalized requests by terminal outcome",
            labelnames=("outcome",))
        self.admission = registry.counter(
            "scls_admission_total",
            "Admission verdicts by action and reason code",
            labelnames=("action", "reason"))
        self.reprefill = registry.counter(
            "scls_reprefill_tokens_total",
            "Tokens re-prefilled beyond each request's first prefill")
        self.prefix_hit = registry.counter(
            "scls_prefix_hit_tokens_total",
            "Prompt tokens satisfied by a shared-prefix page join "
            "instead of prefill (COW paged KV)")
        self.queue_depth = registry.gauge(
            "scls_queue_depth",
            "Requests waiting to be dispatched (pool + worker queues)")
        self.in_flight = registry.gauge(
            "scls_in_flight_slices",
            "Slices currently executing across workers")
        self.free_pages = registry.gauge(
            "scls_kv_free_pages",
            "Free KV pages across workers (paged layout)")
        self.retained = registry.gauge(
            "scls_kv_retained_blocks",
            "Prefix KV blocks retained across slices (kv_retain=request)")
        self.evictions = registry.gauge(
            "scls_kv_evictions",
            "Cumulative resident-prefix evictions under pool pressure")
        self.shared_blocks = registry.gauge(
            "scls_kv_shared_blocks",
            "KV pages currently referenced by more than one request "
            "(refcounted prefix sharing)")


class Observability:
    """One bundle of tracer + metrics + decision audit — module docstring."""

    def __init__(self, tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 audit: Optional[DecisionLog] = None):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.registry = registry
        self.ins: Optional[ServingInstruments] = (
            ServingInstruments(registry) if registry is not None else None)
        self.audit = audit
        #: the single guard scheduler hot paths test
        self.enabled = (self.tracer.enabled or registry is not None
                        or audit is not None)

    # ------------------------------------------------------------------
    @classmethod
    def off(cls) -> "Observability":
        """The shared disabled bundle (stateless; see :data:`OBS_OFF`)."""
        return OBS_OFF

    @classmethod
    def standard(cls, trace: bool = False,
                 audit_capacity: int = 4096) -> "Observability":
        """Metrics + decision audit (cheap, always useful online);
        Chrome tracing opt-in via ``trace=True``."""
        return cls(tracer=Tracer() if trace else None,
                   registry=MetricsRegistry(),
                   audit=DecisionLog(audit_capacity)
                   if audit_capacity > 0 else None)

    def attach(self, core: "SchedulerCore") -> None:
        """Bind this bundle to one scheduler: the trace clock becomes the
        core's discrete-event clock (virtual on sim; advanced by measured
        wall time on real) and the worker tracks are declared."""
        self.tracer.set_clock(lambda: core.now)
        for w in range(core.n_workers):
            self.tracer.declare_worker(w)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def export(self, trace_path: str) -> List[str]:
        """Write the Chrome trace to ``trace_path`` and (when auditing)
        the decision ring next to it; returns the paths written."""
        self.tracer.export(trace_path)
        written = [trace_path]
        if self.audit is not None:
            dpath = decisions_path_for(trace_path)
            with open(dpath, "w") as f:
                json.dump(self.audit.to_list(), f, sort_keys=True)
            written.append(dpath)
        return written

    # ------------------------------------------------------------------
    # scheduler hooks (call sites guard on ``obs.enabled``)
    # ------------------------------------------------------------------
    def _sample(self, core: "SchedulerCore") -> None:
        """Refresh the load gauges + counter tracks from live state."""
        depth = len(core.pool) + sum(
            len(w.pending) + sum(b.size for b in w.queue)
            for w in core.workers)
        in_flight = sum(1 for w in core.workers if w.busy)
        tr = self.tracer
        if tr.enabled:
            tr.counter("queue_depth", depth)
            tr.counter("in_flight_slices", in_flight)
        ins = self.ins
        if ins is not None:
            ins.queue_depth.set(depth)
            ins.in_flight.set(in_flight)
        snap = getattr(core.backend, "obs_snapshot", None)
        if snap is not None:
            s = snap()
            if s:
                if tr.enabled:
                    for key in ("free_pages", "retained_blocks"):
                        if key in s:
                            tr.counter(key, s[key])
                if ins is not None:
                    if "free_pages" in s:
                        ins.free_pages.set(s["free_pages"])
                    if "retained_blocks" in s:
                        ins.retained.set(s["retained_blocks"])
                    if "evictions" in s:
                        ins.evictions.set(s["evictions"])
                    if "shared_blocks" in s:
                        ins.shared_blocks.set(s["shared_blocks"])

    def on_arrival(self, core: "SchedulerCore", req: "Request") -> None:
        tr = self.tracer
        if tr.enabled:
            tr.instant("arrival", core.now, args=dict(
                rid=req.rid, input_len=req.effective_input_len))
            tr.async_begin("request", req.rid, req.arrival, args=dict(
                rid=req.rid, input_len=req.input_len,
                max_gen=req.max_gen, deadline=req.deadline))
        self._sample(core)

    def on_admission(self, core: "SchedulerCore",
                     decision: "AdmissionDecision", *, input_len: int,
                     declared_gen: int, deadline: Optional[float],
                     rid: Optional[int] = None) -> None:
        """One admission verdict (rejects have no rid — none was ever
        assigned)."""
        reason = decision.reason_code or ""
        if self.ins is not None:
            self.ins.admission.inc(action=decision.action, reason=reason)
        if self.tracer.enabled:
            self.tracer.instant(
                f"admission:{decision.action}", core.now,
                cat="admission",
                args=dict(rid=rid, reason=reason,
                          predicted_completion=decision.predicted_completion))
        if self.audit is not None:
            self.audit.record(
                "admission", core.now, rid=rid, action=decision.action,
                reason=reason, input_len=int(input_len),
                declared_gen=int(declared_gen), deadline=deadline,
                queue_delay=decision.queue_delay,
                service_est=decision.service_est,
                gen_cap=decision.gen_cap,
                predicted_completion=decision.predicted_completion,
                max_gen=decision.max_gen)

    def on_schedule(self, core: "SchedulerCore",
                    assignments: Sequence[Tuple[int, "Batch"]],
                    loads_before: Dict[int, float]) -> None:
        """One central-tick scheduling round: audit every batch
        composition (Alg. 1) and every placement (Eq. 10–11).

        ``loads_before`` is the offloader's per-worker load snapshot taken
        *before* ``assign``; both offloaders charge ``est_time`` in
        assignment order, so replaying that bookkeeping reconstructs the
        exact loads each placement decision saw.
        """
        if self.audit is None and not self.tracer.enabled:
            return
        from repro.core.batcher import batch_audit_fields
        loads = dict(loads_before)
        for w, b in assignments:
            rids = sorted(r.rid for r in b.requests)
            if self.audit is not None:
                self.audit.record("batch", core.now,
                                  **batch_audit_fields(b, core.mem))
                self.audit.record(
                    "offload", core.now, rids=rids, worker=w,
                    est_time=float(b.est_time),
                    loads={str(k): round(v, 9)
                           for k, v in sorted(loads.items())})
            if self.tracer.enabled:
                self.tracer.instant("offload", core.now, cat="offload",
                                    args=dict(worker=w, rids=rids))
            loads[w] = loads.get(w, 0.0) + float(b.est_time)
        self._sample(core)

    def on_dispatch(self, core: "SchedulerCore", wid: int, b: "Batch",
                    duration: float,
                    prefill_dur: Optional[float]) -> None:
        """One static slice dispatched: the span on the worker track plus
        prefill/decode sub-spans when the backend measured them."""
        ins = self.ins
        if ins is not None:
            ins.slices.inc()
            ins.slice_time.observe(duration)
        tr = self.tracer
        if not tr.enabled:
            return
        rids = sorted(r.rid for r in b.requests)
        tid = tr.declare_worker(wid)
        # slice index per member = completed slices so far (n_schedules
        # increments when the slice completes)
        tr.complete("slice", core.now, duration, tid=tid, cat="slice",
                    args=dict(rids=rids,
                              input_len=int(b.input_len),
                              slice_len=int(b.slice_len),
                              slice_idx={str(r.rid): r.n_schedules
                                         for r in b.requests}))
        if prefill_dur is not None:
            p = min(max(prefill_dur, 0.0), duration)
            tr.complete("prefill", core.now, p, tid=tid, cat="phase")
            tr.complete("decode", core.now + p, duration - p, tid=tid,
                        cat="phase")

    def on_slice_done(self, core: "SchedulerCore", wid: int, b: "Batch",
                      reprefill_tokens: int, prefix_hit_tokens: int = 0,
                      shared_blocks: int = 0) -> None:
        ins = self.ins
        if ins is not None:
            ins.reprefill.inc(reprefill_tokens)
            ins.reprefill_hist.observe(reprefill_tokens)
            if prefix_hit_tokens:
                ins.prefix_hit.inc(prefix_hit_tokens)
        # audit only slices where a prefix join actually happened, so
        # sharing-free runs produce byte-identical decision logs (the
        # golden-equivalence guard relies on this)
        if self.audit is not None and prefix_hit_tokens:
            self.audit.record(
                "prefix_share", core.now, worker=wid,
                rids=sorted(r.rid for r in b.requests),
                prefix_hit_tokens=int(prefix_hit_tokens),
                shared_blocks=int(shared_blocks))
        self._sample(core)

    def on_cont_dispatch(self, core: "SchedulerCore", wid: int,
                         rids: Sequence[int], duration: float) -> None:
        """One continuous-mode span (ILS iteration run / SCLS-CB lease
        span) dispatched on worker ``wid``."""
        ins = self.ins
        if ins is not None:
            ins.slices.inc()
            ins.slice_time.observe(duration)
        tr = self.tracer
        if tr.enabled:
            tid = tr.declare_worker(wid)
            tr.complete("cont", core.now, duration, tid=tid, cat="slice",
                        args=dict(rids=sorted(rids)))

    def on_cont_done(self, core: "SchedulerCore", wid: int) -> None:
        self._sample(core)

    def on_finalize(self, core: "SchedulerCore", req: "Request",
                    completed: bool) -> None:
        outcome = "completed" if completed else "cancelled"
        ins = self.ins
        if ins is not None:
            ins.requests.inc(outcome=outcome)
            if completed:
                ins.response.observe(core.now - req.arrival)
                if req.first_token_time is not None:
                    ins.ttft.observe(req.first_token_time - req.arrival)
        tr = self.tracer
        if tr.enabled:
            tr.instant("finalize", core.now, args=dict(rid=req.rid,
                                                       outcome=outcome))
            tr.async_end("request", req.rid, core.now,
                         args=dict(outcome=outcome,
                                   generated=req.generated,
                                   n_schedules=req.n_schedules))


#: the one shared disabled bundle — every hook call site guards on
#: ``obs.enabled`` so bare cores (offline paper replays, goldens) pay one
#: attribute read per hook point
OBS_OFF = Observability()
