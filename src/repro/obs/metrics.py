"""Dependency-free Prometheus-style metrics (``repro.obs`` pillar 2).

A tiny in-process registry of counters, gauges, and histograms with
explicit buckets, rendered in the Prometheus text exposition format
(version 0.0.4) — what ``GET /metrics`` serves.  No client library is
involved: the repo's container must not grow dependencies, and the subset
needed here (no summaries, no exemplars, single process) is ~200 lines.

Semantics follow the Prometheus data model:

  * ``Counter`` — monotonically increasing; rendered with a ``_total``
    suffix if the declared name does not already end in one.
  * ``Gauge`` — a value that goes up and down (queue depth, free pages).
  * ``Histogram`` — observations bucketed by ``le`` upper bounds; the
    rendered series are **cumulative** ``<name>_bucket{le="..."}`` counts
    ending in ``le="+Inf"``, plus ``<name>_sum`` and ``<name>_count``
    (the invariants ``bucket[+Inf] == count`` and monotone buckets are
    pinned by ``tests/test_obs.py``).

Labels: a metric is declared with a fixed tuple of label *names*; each
observation addresses a child by label *values* (``c.inc(1, reason="x")``).
Everything is plain dict arithmetic — no locks, because the serving stack
mutates metrics only from the scheduler loop (single-threaded by the
AsyncSliceServer invariant) and HTTP rendering reads are tolerant of a
concurrent increment.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_TIME_BUCKETS", "DEFAULT_TOKEN_BUCKETS"]

#: latency-style buckets (seconds): sub-ms to minutes, roughly 1-2-5
DEFAULT_TIME_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                        0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
                        120.0, 300.0)
#: token-count buckets (powers of two up to 8k)
DEFAULT_TOKEN_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024,
                         2048, 4096, 8192)

_LabelKey = Tuple[str, ...]


def _format_value(v: float) -> str:
    """Prometheus sample formatting: integral values without the trailing
    ``.0``, non-finite as +Inf/-Inf/NaN."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r"\"")


def _render_labels(names: Sequence[str], values: _LabelKey,
                   extra: Optional[Tuple[str, str]] = None) -> str:
    pairs = [f'{n}="{_escape_label(str(v))}"'
             for n, v in zip(names, values)]
    if extra is not None:
        pairs.append(f'{extra[0]}="{extra[1]}"')
    return "{" + ",".join(pairs) + "}" if pairs else ""


class _Metric:
    """Shared declaration state (name, help, label names)."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)

    def _key(self, labels: Dict[str, str]) -> _LabelKey:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def render(self) -> List[str]:
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[_LabelKey, float] = {}
        if not self.labelnames:
            self._values[()] = 0.0

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc({amount}))")
        k = self._key(labels)
        self._values[k] = self._values.get(k, 0.0) + amount

    def value(self, **labels: str) -> float:
        return self._values.get(self._key(labels), 0.0)

    @property
    def sample_name(self) -> str:
        return (self.name if self.name.endswith("_total")
                else self.name + "_total")

    def render(self) -> List[str]:
        out = [f"# HELP {self.sample_name} {self.help}",
               f"# TYPE {self.sample_name} counter"]
        for k in sorted(self._values):
            out.append(f"{self.sample_name}"
                       f"{_render_labels(self.labelnames, k)} "
                       f"{_format_value(self._values[k])}")
        return out


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[_LabelKey, float] = {}
        if not self.labelnames:
            self._values[()] = 0.0

    def set(self, value: float, **labels: str) -> None:
        self._values[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        k = self._key(labels)
        self._values[k] = self._values.get(k, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: str) -> float:
        return self._values.get(self._key(labels), 0.0)

    def render(self) -> List[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} gauge"]
        for k in sorted(self._values):
            out.append(f"{self.name}{_render_labels(self.labelnames, k)} "
                       f"{_format_value(self._values[k])}")
        return out


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        bs = tuple(float(b) for b in buckets)
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(f"{name}: buckets must be a non-empty "
                             f"strictly increasing sequence, got {buckets}")
        self.buckets = bs  # upper bounds, +Inf implicit
        # per child: [per-bucket counts..., +Inf count], sum
        self._counts: Dict[_LabelKey, List[int]] = {}
        self._sums: Dict[_LabelKey, float] = {}
        if not self.labelnames:
            self._child(())

    def _child(self, k: _LabelKey) -> List[int]:
        c = self._counts.get(k)
        if c is None:
            c = self._counts[k] = [0] * (len(self.buckets) + 1)
            self._sums[k] = 0.0
        return c

    def observe(self, value: float, **labels: str) -> None:
        k = self._key(labels)
        c = self._child(k)
        self._sums[k] += value
        for i, b in enumerate(self.buckets):
            if value <= b:
                c[i] += 1
                return
        c[-1] += 1  # above every finite bound: +Inf only

    def count(self, **labels: str) -> int:
        k = self._key(labels)
        return sum(self._counts.get(k, ()))

    def sum(self, **labels: str) -> float:
        return self._sums.get(self._key(labels), 0.0)

    def render(self) -> List[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} histogram"]
        for k in sorted(self._counts):
            cum = 0
            for i, b in enumerate(self.buckets):
                cum += self._counts[k][i]
                out.append(
                    f"{self.name}_bucket"
                    f"{_render_labels(self.labelnames, k, ('le', _format_value(b)))} "
                    f"{cum}")
            cum += self._counts[k][-1]
            out.append(f"{self.name}_bucket"
                       f"{_render_labels(self.labelnames, k, ('le', '+Inf'))} "
                       f"{cum}")
            out.append(f"{self.name}_sum"
                       f"{_render_labels(self.labelnames, k)} "
                       f"{_format_value(self._sums[k])}")
            out.append(f"{self.name}_count"
                       f"{_render_labels(self.labelnames, k)} {cum}")
        return out


class MetricsRegistry:
    """Declaration + rendering home for one process's metrics."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, m: _Metric) -> _Metric:
        prev = self._metrics.get(m.name)
        if prev is not None:
            if type(prev) is not type(m) \
                    or prev.labelnames != m.labelnames:
                raise ValueError(f"metric {m.name!r} re-registered with a "
                                 f"different type or labels")
            return prev  # idempotent re-declaration
        self._metrics[m.name] = m
        return m

    def counter(self, name: str, help: str,
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter(name, help, labelnames))  # type: ignore[return-value]

    def gauge(self, name: str, help: str,
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge(name, help, labelnames))  # type: ignore[return-value]

    def histogram(self, name: str, help: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  labelnames: Sequence[str] = ()) -> Histogram:
        return self._register(Histogram(name, help, buckets, labelnames))  # type: ignore[return-value]

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def __iter__(self) -> Iterable[_Metric]:
        return iter(self._metrics.values())

    def render(self) -> str:
        """The full Prometheus text exposition (trailing newline included,
        as the format requires)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"
