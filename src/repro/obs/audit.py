"""Scheduler decision audit (``repro.obs`` pillar 3).

The scheduler computes the paper's Eq. 1–2 service estimates and
Eq. 10–11 queue/load signals at every decision point — and, before this
module, threw them away the moment the decision was made.  The
:class:`DecisionLog` is a bounded ring buffer of structured decision
events so an operator (or a test) can answer "*why* was request 17
rejected / batched with those peers / placed on worker 3":

  * ``kind="admission"`` — one event per admission verdict: action
    (accept/reject/degrade), reason code, the Eq. 1–2 service estimate,
    the Eq. 10–11 predicted queue delay, the calibrated generation cap,
    and the deadline it was compared against;
  * ``kind="batch"`` — one event per ``dp_batch`` /
    ``bucketed_pred_batch`` composition: member rids, the chosen slice
    length S, the batch input length, the Eq. 1–4 estimated serving
    time, and the memory bound (Eq. 5–9 ``max_batch_size``) the no-OOM
    constraint enforced;
  * ``kind="offload"`` — one event per placement: the chosen worker and
    every worker's Eq. 11 load *at decision time* (reconstructed from
    the offloader's greedy bookkeeping order).

Events are plain dicts (JSON-ready) with a monotone ``seq`` and the core
timestamp ``ts``; the ring drops the oldest events at capacity so a
serve-forever deployment holds bounded memory.  Query via
:meth:`DecisionLog.query` (``GET /debug/decisions`` upstream) or dump the
whole ring alongside a trace (``serve --trace-out``).
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Deque, List, Optional

__all__ = ["DecisionLog"]


class DecisionLog:
    """Ring buffer of structured scheduler decisions — module docstring."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._ring: Deque[dict] = deque(maxlen=self.capacity)
        self._seq = itertools.count()
        #: total events ever recorded (>= len(ring) once it wraps)
        self.n_recorded = 0

    # ------------------------------------------------------------------
    def record(self, kind: str, ts: float, **fields) -> dict:
        """Append one decision event; returns the stored dict."""
        ev = dict(seq=next(self._seq), ts=float(ts), kind=kind, **fields)
        self._ring.append(ev)
        self.n_recorded += 1
        return ev

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._ring)

    def _touches(self, ev: dict, rid: int) -> bool:
        if ev.get("rid") == rid:
            return True
        rids = ev.get("rids")
        return bool(rids) and rid in rids

    def query(self, rid: Optional[int] = None, kind: Optional[str] = None,
              limit: Optional[int] = None) -> List[dict]:
        """Events matching the filters, oldest first.

        ``rid`` matches events whose ``rid`` equals it or whose ``rids``
        list contains it; ``limit`` keeps the *newest* N of the matches
        (the interesting end of a ring buffer).
        """
        out = [ev for ev in self._ring
               if (kind is None or ev["kind"] == kind)
               and (rid is None or self._touches(ev, rid))]
        if limit is not None and limit >= 0:
            out = out[len(out) - min(limit, len(out)):]
        return out

    def to_list(self) -> List[dict]:
        """Every retained event, oldest first (the ``--trace-out`` dump)."""
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()
