"""Chrome trace-event tracer for the serving stack (``repro.obs`` pillar 1).

Records *spans* (``ph="X"`` complete events), *instants* (``ph="i"``),
*async request-lifecycle spans* (``ph="b"``/``"e"``, one per rid), and
*counter tracks* (``ph="C"``) in the Chrome trace-event JSON format, so a
``trace.json`` exported here loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.

Timeline convention: every timestamp is **core time** — the scheduler's
discrete-event clock.  On the sim backend that is virtual time, so the
trace visualizes the discrete-event timeline exactly; on the real backend
core time advances by the *measured wall time* of each worker's own
batches (what N parallel machines would observe), and the prefill/decode
sub-spans inside a slice come from wall-clock timed sections in
``StaticEngine``/``RealBackend``.  Durations are therefore real wall
durations on the real backend and model durations on the sim backend.

Overhead discipline: tracing must never perturb scheduling (the golden
dispatch logs are asserted bit-exact with tracing on) and must cost near
zero when disabled.  The disabled tracer is :data:`NULL_TRACER` — every
method is a no-op ``pass`` and hot paths guard bulk work behind
``tracer.enabled``.  Nothing in this module draws randomness or reads
wall clocks on the sim path, so same seed ⇒ byte-identical trace.

Track layout (Perfetto rows):

  * pid 1 ("scheduler") / tid 0 ("control") — arrivals, admission
    verdicts, scheduling ticks;
  * pid 1 / tid 100+w ("worker w") — per-worker slice spans with nested
    prefill/decode sections;
  * counter tracks (pid 1): ``queue_depth``, ``in_flight_slices``,
    ``free_pages``, ``retained_blocks``;
  * pid 2 ("requests") — async lifecycle spans, one per rid
    (arrival → finalize), carrying the terminal outcome.
"""
from __future__ import annotations

import json
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["Tracer", "NULL_TRACER", "PID_SCHED", "PID_REQUESTS",
           "TID_CONTROL", "worker_tid"]

#: process ids of the two Perfetto "processes" (see module docstring)
PID_SCHED = 1
PID_REQUESTS = 2
#: tid of the scheduler control track (arrivals / ticks / admission)
TID_CONTROL = 0
_TID_WORKER_BASE = 100


def worker_tid(wid: int) -> int:
    """Trace thread id of worker ``wid`` (its Perfetto row)."""
    return _TID_WORKER_BASE + int(wid)


def _us(t: float) -> float:
    """Seconds → trace microseconds, rounded so exports are stable across
    platforms (0.1 ns granularity is far below any modeled duration)."""
    return round(t * 1e6, 4)


class Tracer:
    """Collects trace events against a pluggable clock.

    ``clock`` returns the current time in seconds; the serving stack binds
    it to ``SchedulerCore.now`` (see :meth:`repro.obs.hub.Observability.
    attach`) so all events share the core timeline.  Construct, attach,
    run, then :meth:`export` / :meth:`to_dict`.
    """

    #: hot paths may skip argument marshalling when this is False
    enabled: bool = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._clock: Callable[[], float] = clock or (lambda: 0.0)
        self._events: List[dict] = []
        #: (pid, tid) -> row name; rendered as metadata events on export
        self._tracks: Dict[Tuple[int, int], str] = {
            (PID_SCHED, TID_CONTROL): "control"}
        self._process_names: Dict[int, str] = {PID_SCHED: "scheduler",
                                               PID_REQUESTS: "requests"}

    # ------------------------------------------------------------------
    # clock / track plumbing
    # ------------------------------------------------------------------
    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    @property
    def now(self) -> float:
        """Current trace time in seconds (the bound clock)."""
        return self._clock()

    def declare_worker(self, wid: int) -> int:
        """Name worker ``wid``'s track; returns its tid."""
        tid = worker_tid(wid)
        self._tracks.setdefault((PID_SCHED, tid), f"worker {wid}")
        return tid

    # ------------------------------------------------------------------
    # event emitters (all timestamps in seconds; stored as trace µs)
    # ------------------------------------------------------------------
    def complete(self, name: str, ts: float, dur: float, *,
                 tid: int = TID_CONTROL, cat: str = "sched",
                 args: Optional[dict] = None) -> None:
        """A span ``[ts, ts+dur]`` on one track (``ph="X"``)."""
        ev = dict(name=name, ph="X", ts=_us(ts), dur=_us(max(dur, 0.0)),
                  pid=PID_SCHED, tid=tid, cat=cat)
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, ts: Optional[float] = None, *,
                tid: int = TID_CONTROL, cat: str = "sched",
                args: Optional[dict] = None) -> None:
        """A zero-duration marker (``ph="i"``, thread-scoped)."""
        ev = dict(name=name, ph="i", s="t",
                  ts=_us(self._clock() if ts is None else ts),
                  pid=PID_SCHED, tid=tid, cat=cat)
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, name: str, value: float,
                ts: Optional[float] = None) -> None:
        """One sample on counter track ``name`` (``ph="C"``)."""
        self._events.append(dict(
            name=name, ph="C", ts=_us(self._clock() if ts is None else ts),
            pid=PID_SCHED, tid=TID_CONTROL, cat="counter",
            args={name: value}))

    def async_begin(self, name: str, aid: int, ts: Optional[float] = None,
                    *, cat: str = "request",
                    args: Optional[dict] = None) -> None:
        """Open async span ``aid`` (``ph="b"``) on the requests process."""
        ev = dict(name=name, ph="b", id=int(aid), cat=cat,
                  ts=_us(self._clock() if ts is None else ts),
                  pid=PID_REQUESTS, tid=TID_CONTROL)
        if args:
            ev["args"] = args
        self._events.append(ev)

    def async_end(self, name: str, aid: int, ts: Optional[float] = None,
                  *, cat: str = "request",
                  args: Optional[dict] = None) -> None:
        """Close async span ``aid`` (``ph="e"``)."""
        ev = dict(name=name, ph="e", id=int(aid), cat=cat,
                  ts=_us(self._clock() if ts is None else ts),
                  pid=PID_REQUESTS, tid=TID_CONTROL)
        if args:
            ev["args"] = args
        self._events.append(ev)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        self._events.clear()

    def to_dict(self) -> dict:
        """The Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Metadata events naming processes/threads are prepended so Perfetto
        labels every row; event order within the list is the deterministic
        emission order (the viewer sorts by ``ts`` anyway).
        """
        meta: List[dict] = []
        for pid, pname in sorted(self._process_names.items()):
            meta.append(dict(name="process_name", ph="M", pid=pid, tid=0,
                             args={"name": pname}))
        for (pid, tid), tname in sorted(self._tracks.items()):
            meta.append(dict(name="thread_name", ph="M", pid=pid, tid=tid,
                             args={"name": tname}))
            # thread_sort_index keeps worker rows in wid order
            meta.append(dict(name="thread_sort_index", ph="M", pid=pid,
                             tid=tid, args={"sort_index": tid}))
        return {"traceEvents": meta + self._events,
                "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        """Deterministic serialization (same events ⇒ same bytes)."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


class _NullTracer(Tracer):
    """The disabled tracer: every emitter is a no-op ``pass`` so traced
    call sites cost one attribute lookup + an empty call when tracing is
    off (plus most sites guard on ``tracer.enabled`` and skip argument
    construction entirely)."""

    enabled = False

    def __init__(self):
        super().__init__()

    def set_clock(self, clock) -> None:  # noqa: D102 — no-op family
        pass

    def declare_worker(self, wid: int) -> int:
        return worker_tid(wid)

    def complete(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def counter(self, *a, **kw) -> None:
        pass

    def async_begin(self, *a, **kw) -> None:
        pass

    def async_end(self, *a, **kw) -> None:
        pass


#: the shared disabled tracer (stateless — safe to share everywhere)
NULL_TRACER = _NullTracer()
