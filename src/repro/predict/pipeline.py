"""Shared pred-mode glue for the cluster runtimes.

Both ``cluster.simulator`` (virtual time) and ``cluster.realtime`` (real
JAX engines) advertise running *the same scheduler code*; this module is
what keeps that true for the prediction path: predictor/calibrator
construction, the schedule-time observe→predict→calibrate→batch sequence,
and the completion feedback live here exactly once.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.batcher import bucketed_pred_batch
from repro.predict.base import LengthPredictor
from repro.predict.calibration import QuantileCalibrator


class PredictionPipeline:
    """Owns the predictor + calibrator for one pred-mode cluster run."""

    def __init__(self, strategy, predictor: Optional[LengthPredictor] = None):
        from repro.predict import make_predictor
        self.s = strategy
        self.predictor = predictor or make_predictor(
            strategy.predictor or "histogram", max_gen=strategy.max_gen,
            coverage=strategy.coverage)
        self.calibrator = QuantileCalibrator(coverage=strategy.coverage)

    def batches(self, reqs: Sequence, est, mem) -> List:
        """One scheduling round: censored survival evidence, calibrated
        remaining-length caps, then slice-aware bucketed batching."""
        for r in reqs:
            self.predictor.observe_alive(r)
        caps = {r.rid: self.calibrator.cap(
            r, self.predictor.predict_remaining(r)) for r in reqs}
        return bucketed_pred_batch(reqs, caps, self.s.slice_len, est, mem,
                                   phi=self.s.bucket_phi,
                                   min_slice=self.s.min_pred_slice,
                                   packing=self.s.packing)

    def on_complete(self, req) -> None:
        """Online-learning feedback: every completed request trains the
        predictor and scores its calibrated predictions."""
        self.predictor.observe(req)
        self.calibrator.observe(req)
