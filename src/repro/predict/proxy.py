"""Proxy-model length predictor: a small JAX MLP head trained online.

Follows the proxy-predictor line (arXiv 2404.08509): a model orders of
magnitude cheaper than the served LLM predicts generation length from
request features available at schedule time.  Here the head is a 2-layer
MLP over cheap scalar features (log input length, tokens generated so far,
and a prompt summary statistic), regressing ``log1p(remaining)``; it is
fitted online by mini-batch SGD on completed requests, so it needs no
offline training set and adapts to the live workload.

On synthetic traces whose generation lengths are drawn independently of
the prompt, the MLP can only learn the conditional marginal — i.e. it
degrades gracefully to a histogram-mean-like predictor.  On real corpora
the prompt features (and any richer ones added to ``_features``) carry
signal, which is the point of the proxy-model design.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.predict.base import LengthPredictor

_N_FEATURES = 4
_HIDDEN = 16
_BATCH = 32


def _init_params(key, hidden: int = _HIDDEN):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (_N_FEATURES, hidden)) * 0.3,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, 1)) * 0.3,
        "b2": jnp.zeros((1,)),
    }


def _forward(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


def _loss(params, x, y, w):
    pred = _forward(params, x)
    return jnp.sum(w * (pred - y) ** 2) / jnp.maximum(jnp.sum(w), 1.0)


@partial(jax.jit, static_argnames=("lr",))
def _sgd_step(params, x, y, w, lr: float = 0.05):
    g = jax.grad(_loss)(params, x, y, w)
    return jax.tree_util.tree_map(lambda p, gi: p - lr * gi, params, g)


class ProxyPredictor(LengthPredictor):
    name = "proxy"

    def __init__(self, max_gen: int = 1024, max_input: int = 1024,
                 lr: float = 0.05, window: int = 512, seed: int = 0,
                 steps_per_observe: int = 1):
        self.max_gen = int(max_gen)
        self.max_input = int(max_input)
        self.lr = float(lr)
        self.steps_per_observe = int(steps_per_observe)
        self.params = _init_params(jax.random.PRNGKey(seed))
        self._buf: List[Tuple[np.ndarray, float]] = []
        self._window = int(window)
        self._cursor = 0
        self.n_observed = 0

    # ------------------------------------------------------------------
    def _features(self, input_len: int, generated: int, prompt) -> np.ndarray:
        prompt_stat = 0.0
        if prompt is not None and len(prompt):
            # cheap content signal: token-id dispersion, scaled to O(1)
            prompt_stat = float(np.std(prompt)) / (1.0 + float(np.mean(prompt)))
        return np.array([
            np.log1p(input_len) / np.log1p(self.max_input),
            np.log1p(generated) / np.log1p(self.max_gen),
            float(generated > 0),
            prompt_stat,
        ], dtype=np.float32)

    # ------------------------------------------------------------------
    def predict_remaining(self, req) -> float:
        x = self._features(req.input_len, req.generated, req.prompt)
        z = float(_forward(self.params, jnp.asarray(x[None, :]))[0])
        rem = float(np.expm1(np.clip(z, 0.0, np.log1p(self.max_gen))))
        return max(rem, 1.0)

    def observe(self, req) -> None:
        # two supervision points per completion: remaining at arrival and a
        # mid-generation conditional, so the `generated` feature is learned
        total = max(req.generated, 1)
        pairs = [(self._features(req.input_len, 0, req.prompt), total)]
        if total > 1:
            g = total // 2
            pairs.append((self._features(req.input_len, g, req.prompt),
                          total - g))
        for x, rem in pairs:
            item = (x, float(np.log1p(rem)))
            if len(self._buf) < self._window:
                self._buf.append(item)
            else:
                self._buf[self._cursor] = item
                self._cursor = (self._cursor + 1) % self._window
        self.n_observed += 1
        for _ in range(self.steps_per_observe):
            self._train_minibatch()

    def _train_minibatch(self) -> None:
        n = len(self._buf)
        if n == 0:
            return
        # deterministic recency-biased minibatch, padded to a fixed shape so
        # the jitted step compiles once
        take = min(_BATCH, n)
        idx = [(len(self._buf) + self._cursor - 1 - i) % n for i in range(take)]
        x = np.zeros((_BATCH, _N_FEATURES), dtype=np.float32)
        y = np.zeros((_BATCH,), dtype=np.float32)
        w = np.zeros((_BATCH,), dtype=np.float32)
        for row, j in enumerate(idx):
            x[row], y[row] = self._buf[j]
            w[row] = 1.0
        self.params = _sgd_step(self.params, jnp.asarray(x), jnp.asarray(y),
                                jnp.asarray(w), lr=self.lr)
