"""Quantile calibration: raw length predictions -> conservative caps.

A raw point prediction is useless to a scheduler without an error model: an
under-predicted request blows through its slice and must be rescheduled
(wasting a prefill), an over-predicted one wastes reserved memory and
invalid tokens.  The calibrator learns a multiplicative correction from the
observed ratio actual/predicted (split-conformal style, over a sliding
window so it tracks both workload and predictor drift):

    cap(r) = clip( raw(r) * Q_coverage(actual/raw history), 1, budget )

so that, when the ratios are exchangeable, P[actual <= cap] ~= coverage.
A perfect predictor yields all-ones ratios and the calibration passes its
predictions through exactly — which is what makes ``scls-pred`` with
:class:`~repro.predict.perfect.PerfectPredictor` reproduce ORACLE.

Mispredictions stay safe: the scheduler serves at most a slice per round
regardless, and an uncompleted request simply goes back to the pool.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Tuple

import numpy as np


class QuantileCalibrator:
    """Turns raw predicted remaining lengths into per-request caps."""

    def __init__(self, coverage: float = 0.7, window: int = 512,
                 min_samples: int = 16, max_scale: float = 32.0):
        assert 0.0 < coverage < 1.0
        self.coverage = float(coverage)
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.max_scale = float(max_scale)
        self.ratios: Deque[float] = deque(maxlen=window)
        # rid -> [(raw prediction, generated at prediction time), ...]: every
        # prediction point is kept and scored — scoring only the final one
        # would systematically flatter the predictor (the last slice of a
        # many-times-rescheduled request is trivially well predicted) and
        # the scale would never correct the early under-predictions
        self._pending: Dict[int, List[Tuple[float, int]]] = {}

    # ------------------------------------------------------------------
    @property
    def scale(self) -> float:
        if len(self.ratios) < self.min_samples:
            return 1.0
        return float(np.clip(np.quantile(np.asarray(self.ratios),
                                         self.coverage),
                             1.0 / self.max_scale, self.max_scale))

    def cap(self, req, raw_remaining: float) -> int:
        """Conservative remaining-length cap for ``req`` (>= 1 token)."""
        self._pending.setdefault(req.rid, []).append(
            (max(float(raw_remaining), 1.0), int(req.generated)))
        budget = max(int(req.max_gen) - int(req.generated), 1)
        return int(np.clip(round(raw_remaining * self.scale), 1, budget))

    def observe(self, req) -> None:
        """Completion feedback: score every prediction made for ``req``."""
        for raw, g_at_pred in self._pending.pop(req.rid, ()):
            actual = max(int(req.generated) - g_at_pred, 1)
            self.ratios.append(actual / raw)

    # ------------------------------------------------------------------
    def empirical_coverage(self) -> float:
        """Fraction of scored predictions with actual <= calibrated cap
        under the *current* scale (diagnostic, used by the benchmark)."""
        if not self.ratios:
            return float("nan")
        r = np.asarray(self.ratios)
        return float(np.mean(r <= self.scale))
