"""Online per-workload histogram/EWMA generation-length predictor.

A discrete hazard (Kaplan–Meier) estimator over generation-length bins,
updated from the live request stream:

  * every *completed* request contributes an event in its final bin
    (``observe``, called by the cluster runtimes' feedback hooks);
  * every *in-flight* request contributes survival evidence for the bins it
    has already outlived (``observe_alive``, called at schedule time).

The censored (in-flight) evidence matters: a predictor trained only on
completions is length-biased — short requests finish first, so for the
whole life of a serving run the completed set under-represents long
requests and conditional quantiles come out systematically low (we
measured calibration having to inflate such a predictor's caps 5–17x to
reach target coverage).  Counting at-risk mass the KM way removes that
bias at the source.

Predictions are conditional quantiles of G | G > g for a request that has
already generated ``g`` valid tokens — the same hazard-style estimate S³
builds from its offline length classifier, but learned online.  All counts
are exponentially decayed per completion (an EWMA over the request
stream), so the predictor tracks workload drift at a rate set by
``decay``.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.predict.base import LengthPredictor


class HistogramPredictor(LengthPredictor):
    name = "histogram"

    def __init__(self, max_gen: int = 1024, n_bins: int = 128,
                 decay: float = 0.999, quantile: float = 0.5,
                 min_observed: int = 8):
        assert 0.0 < decay <= 1.0 and 0.0 < quantile < 1.0
        self.max_gen = int(max_gen)
        self.n_bins = int(n_bins)
        self.decay = float(decay)
        self.quantile = float(quantile)
        self.min_observed = int(min_observed)
        # bin j covers lengths (edges[j], edges[j+1]]
        self.edges = np.linspace(0.0, float(max_gen), n_bins + 1)
        self.at_risk = np.zeros(n_bins)   # requests that entered bin j
        self.events = np.zeros(n_bins)    # requests that finished in bin j
        self._credited: Dict[int, int] = {}  # rid -> bins already credited
        self.n_observed = 0

    # ------------------------------------------------------------------
    def _bin(self, length: float) -> int:
        i = int(np.searchsorted(self.edges, min(length, self.max_gen),
                                side="left")) - 1
        return int(np.clip(i, 0, self.n_bins - 1))

    def _survived_bins(self, generated: int) -> int:
        """Number of leading bins fully outlived by ``generated`` tokens."""
        k = int(np.searchsorted(self.edges, generated, side="right")) - 1
        return int(np.clip(k, 0, self.n_bins))

    def _credit(self, rid: int, upto: int) -> None:
        c = self._credited.get(rid, 0)
        if upto > c:
            self.at_risk[c:upto] += 1.0
            self._credited[rid] = upto

    # ------------------------------------------------------------------
    def observe_alive(self, req) -> None:
        """Censored observation: ``req`` is still generating at
        ``req.generated`` tokens, so it has survived every bin below."""
        self._credit(req.rid, self._survived_bins(req.generated))

    def observe(self, req) -> None:
        total = max(req.generated, 1)
        b = self._bin(total)
        self._credit(req.rid, b)
        self._credited.pop(req.rid, None)
        self.at_risk[b] += 1.0
        self.events[b] += 1.0
        self.at_risk *= self.decay
        self.events *= self.decay
        self.n_observed += 1

    # ------------------------------------------------------------------
    def _survival(self) -> np.ndarray:
        """S[j] = P(G > edges[j+1]) from the discrete hazard."""
        with np.errstate(divide="ignore", invalid="ignore"):
            h = np.where(self.at_risk > 0, self.events / self.at_risk, 0.0)
        return np.cumprod(1.0 - np.clip(h, 0.0, 1.0))

    def predict_total(self, generated: int) -> float:
        """``quantile`` of G | G > generated (total length, not remaining)."""
        if self.n_observed < self.min_observed:
            return float(self.max_gen)  # cold start: fall back to slicing
        S = self._survival()
        k0 = self._survived_bins(generated)
        base = S[k0 - 1] if k0 > 0 else 1.0
        if base <= 0.0:
            return float(self.max_gen)
        target = base * (1.0 - self.quantile)
        for j in range(k0, self.n_bins):
            if S[j] <= target:
                return float(self.edges[j + 1])  # conservative: upper edge
        return float(self.max_gen)

    def predict_remaining(self, req) -> float:
        total = self.predict_total(req.generated)
        return float(max(total - req.generated, 1.0))
