"""Ground-truth predictor: the analysis upper bound (old ORACLE path).

Reads ``Request.gen_len`` — the one component allowed to do so (the
``Request`` docstring bans schedulers from it).  With this predictor,
``scls-pred`` reproduces the ORACLE strategy: requests are grouped by
exact remaining length, short requests finish in a single exact-length
slice, and the gap to the histogram/proxy predictors is the price of
prediction error.
"""
from __future__ import annotations

from repro.predict.base import LengthPredictor


class PerfectPredictor(LengthPredictor):
    name = "perfect"

    def predict_remaining(self, req) -> float:
        return float(max(req.remaining_gen, 1))
