"""Generation-length prediction interface (beyond-paper subsystem).

The paper (§6 Related Work) names length prediction (S³, PiA, proxy-model
predictors) as the main rival line to slice-level scheduling: if the
scheduler knew each request's generation length it could batch requests of
similar remaining length together, pick exact slice lengths, and waste no
invalid tokens.  ``repro.predict`` supplies that knowledge as a pluggable
component:

  * :class:`LengthPredictor` — the interface: ``predict_remaining`` gives a
    raw point estimate of the remaining decode length of a request,
    ``observe`` feeds back every completed request (online learning).
  * ``HistogramPredictor`` — per-workload decayed histogram (EWMA counts)
    of completed generation lengths; predicts conditional quantiles of
    G | G > generated.
  * ``ProxyPredictor`` — a small JAX MLP head over cheap prompt features,
    trained online by SGD (cf. arXiv 2404.08509).
  * ``PerfectPredictor`` — ground truth; subsumes the old ORACLE
    special-case in the simulator and serves as the analysis upper bound.

Predictions are never trusted raw: :mod:`repro.predict.calibration` turns
them into conservative per-request caps at a target coverage, and the
scheduler treats a blown cap as an ordinary unfinished slice (the request
is simply rescheduled), so correctness never depends on the predictor.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.request import Request


class LengthPredictor:
    """Interface: point predictions of remaining generation length."""

    name = "base"

    def predict_remaining(self, req: "Request") -> float:
        """Raw estimate of the remaining decode iterations of ``req``.

        Called at schedule time; may use anything observable by a scheduler
        (input length, tokens generated so far, prompt tokens) but NOT the
        ground-truth ``gen_len`` — only :class:`PerfectPredictor` reads
        that, as an explicitly-labeled analysis bound.
        """
        raise NotImplementedError

    def observe(self, req: "Request") -> None:
        """Feedback hook: ``req`` has completed (``req.generated`` is its
        realized total generation length).  Called by the cluster runtimes
        for every finished request; default is a no-op (stateless
        predictors)."""

    def observe_alive(self, req: "Request") -> None:
        """Censored feedback: ``req`` is being scheduled while still
        generating — evidence that its total length exceeds
        ``req.generated``.  Survival-aware predictors (histogram) use this
        to avoid the length bias of completion-only training; default is a
        no-op."""

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}()"
