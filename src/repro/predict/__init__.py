"""Generation-length prediction subsystem (see ``repro.predict.base``)."""
from __future__ import annotations

from repro.predict.base import LengthPredictor
from repro.predict.calibration import QuantileCalibrator
from repro.predict.histogram import HistogramPredictor
from repro.predict.perfect import PerfectPredictor
from repro.predict.pipeline import PredictionPipeline

__all__ = [
    "LengthPredictor", "QuantileCalibrator", "HistogramPredictor",
    "PerfectPredictor", "PredictionPipeline", "ProxyPredictor",
    "make_predictor", "PREDICTORS",
]

PREDICTORS = ("histogram", "proxy", "perfect")


def make_predictor(name: str, max_gen: int = 1024,
                   coverage: float | None = None, **kw) -> LengthPredictor:
    """Factory used by the cluster runtimes and the serve launcher.

    ``coverage`` is the scheduler's target quantile: a distribution-aware
    predictor (histogram) aims its raw predictions directly at it, so the
    downstream :class:`QuantileCalibrator` only has to correct residual
    bias; point predictors (proxy) ignore it and rely on the calibrator
    entirely.
    """
    name = name.lower()
    if name == "histogram":
        if coverage is not None:
            kw.setdefault("quantile", coverage)
        return HistogramPredictor(max_gen=max_gen, **kw)
    if name == "proxy":
        # imported lazily: pulls in jax, which the pure-simulator path
        # (histogram/perfect) does not need
        from repro.predict.proxy import ProxyPredictor
        return ProxyPredictor(max_gen=max_gen, **kw)
    if name == "perfect":
        return PerfectPredictor()
    raise ValueError(f"unknown predictor {name!r} (have {PREDICTORS})")


def __getattr__(name: str):
    if name == "ProxyPredictor":
        from repro.predict.proxy import ProxyPredictor
        return ProxyPredictor
    raise AttributeError(name)
