"""Continuous-batching (iteration-level) engine — the ILS baseline, real JAX.

Slot-based, DeepSpeed-FastGen-like semantics:
  * a fixed number of slots (= the conservative parallelism cap the paper
    criticizes);
  * at every iteration boundary, finished requests exit and waiting requests
    join (FCFS), each join paying its own prefill;
  * no padding or invalid tokens are ever generated.

KV layouts (``kv_layout``):
  * ``"dense"`` — each slot owns a contiguous W-slot region of a shared
    cache, reserved worst-case at engine construction; parallelism is
    capped by ``max_slots`` regardless of how short requests actually are.
  * ``"paged"`` — K/V live in a shared page pool (``repro.kvcache``); a
    request joining reserves exactly its slice envelope
    ``bucketed(L_i) + min(forced, max_gen)`` tokens of pages (paper Eq. 5)
    and frees them on exit, so under the same byte budget short requests
    pack many more parallel rows.  Token outputs are exact vs. dense: the
    logical slot/position arithmetic is identical, only the physical
    placement differs (per-row block tables, ``models.transformer.
    decode_step_paged`` → ``kernels.paged_decode_attention``).

Rows advance independently via per-row write slots.  Dense-family models
only (the baseline is evaluated on llama-family, as in the paper where
FastGen serves LLaMA2).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import bucket_len
from repro.engine.sampling import greedy
from repro.kvcache import (PageAllocator, clear_row, init_paged_kv_cache,
                           write_prefill_pages)
from repro.models import transformer
from repro.models.attention import KVCache, init_kv_cache
from repro.models.registry import Model


class _Slot:
    __slots__ = ("req_idx", "cached", "base", "gen", "cur", "forced")

    def __init__(self):
        self.req_idx = -1
        self.cached = 0
        self.base = 0  # padded prefill width: decode writes go at base + gen
        self.gen = 0
        self.cur = 0
        self.forced = 1 << 30


class ContinuousEngine:
    def __init__(self, model: Model, params, max_slots: int = 8,
                 max_context: int = 2048, eos_id: int = 1, pad_id: int = 0,
                 len_bucket: int = 16, kv_layout: str = "dense",
                 page_tokens: int = 16,
                 total_kv_tokens: Optional[int] = None):
        assert model.cfg.family in ("dense",), "ILS engine: dense family only"
        assert kv_layout in ("dense", "paged"), kv_layout
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.W = max_context
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.len_bucket = len_bucket
        self.kv_layout = kv_layout
        cfg = model.cfg
        if kv_layout == "paged":
            if self.W % page_tokens:
                raise ValueError(f"max_context {self.W} must be a multiple "
                                 f"of page_tokens {page_tokens}")
            self.page_tokens = page_tokens
            # byte-budget parity with dense by default: same slot count
            # worth of cache, but allocated block by block on demand
            total = (total_kv_tokens if total_kv_tokens is not None
                     else max_slots * self.W)
            if total % page_tokens:
                raise ValueError(f"total_kv_tokens {total} must be a "
                                 f"multiple of page_tokens {page_tokens}")
            self.alloc: Optional[PageAllocator] = PageAllocator(
                total // page_tokens, page_tokens)
            self.cache = init_paged_kv_cache(
                cfg.n_layers, max_slots, self.alloc.n_pages, page_tokens,
                self.W // page_tokens, cfg.n_kv_heads, cfg.head_dim,
                cfg.dtype)
            self._decode = jax.jit(
                lambda p, c, t, qp, sl: transformer.decode_step_paged(
                    p, cfg, c, t, qp, sl))
        else:
            self.alloc = None
            self.cache = init_kv_cache(cfg.n_layers, max_slots, self.W,
                                       cfg.n_kv_heads, cfg.head_dim, cfg.dtype)
            self._decode = jax.jit(
                lambda p, c, t, qp, sl: transformer.decode_step_rowslots(
                    p, cfg, c, t, qp, sl))
        self._prefill = jax.jit(
            lambda p, t, l: transformer.prefill(p, cfg, t, l, self.W),
            static_argnums=())

    # ------------------------------------------------------------------
    def _run_prefill(self, prompt: np.ndarray):
        L = bucket_len(len(prompt), self.len_bucket)
        toks = np.full((1, L), self.pad_id, np.int32)
        toks[0, L - len(prompt):] = prompt
        last_logits, single = self._prefill(self.params, jnp.asarray(toks),
                                            jnp.asarray([len(prompt)], np.int32))
        return int(np.asarray(greedy(last_logits))[0]), L, single

    def _insert(self, row: int, prompt: np.ndarray):
        """Dense join: returns (first_token, padded_prefill_width)."""
        first, L, single = self._run_prefill(prompt)
        c = self.cache
        self.cache = KVCache(
            k=c.k.at[:, row].set(single.k[:, 0]),
            v=c.v.at[:, row].set(single.v[:, 0]),
            slot_pos=c.slot_pos.at[row].set(single.slot_pos[0]),
            write_idx=c.write_idx,
            lengths=c.lengths.at[row].set(len(prompt)),
        )
        return first, L

    def _insert_paged(self, row: int, prompt: np.ndarray, pages: List[int]):
        """Paged join: scatter the prefill K/V into the reserved pages."""
        first, L, single = self._run_prefill(prompt)
        T = len(pages) * self.page_tokens  # covers prefill + decode envelope
        self.cache = write_prefill_pages(
            self.cache, row, pages, single.k[:, 0, :T], single.v[:, 0, :T],
            np.asarray(single.slot_pos[0, :T]), len(prompt))
        return first, L

    def _tokens_needed(self, prompt_len: int, forced_cap: int) -> int:
        """The slice envelope (L_i + S) this join must reserve — Eq. 5."""
        base = bucket_len(prompt_len, self.len_bucket)
        return min(base + forced_cap, self.W)

    # ------------------------------------------------------------------
    def serve(self, prompts: Sequence[np.ndarray],
              forced_gen_lens: Optional[Sequence[int]] = None,
              max_gen: int = 1024, max_iters: int = 100000) -> "ContinuousResult":
        """Serve all prompts to completion with continuous batching."""
        n = len(prompts)
        forced = list(forced_gen_lens) if forced_gen_lens is not None else [1 << 30] * n
        if self.kv_layout == "paged":
            # validate every envelope BEFORE any reservation: raising
            # mid-run would leak in-flight requests' pages and discard
            # their outputs (a never-fitting request can't just wait —
            # it would silently starve itself and everything FCFS behind)
            for i, p in enumerate(prompts):
                need = self._tokens_needed(len(p), min(forced[i], max_gen))
                if self.alloc.blocks_for_tokens(need) > self.alloc.n_pages:
                    raise ValueError(
                        f"request {i}: envelope of {need} tokens exceeds "
                        f"the page pool ({self.alloc.n_pages} x "
                        f"{self.page_tokens})")
        waiting = list(range(n))
        slots = [_Slot() for _ in range(self.max_slots)]
        outputs: List[List[int]] = [[] for _ in range(n)]
        join_order: List[int] = []
        concurrency: List[int] = []
        t0 = time.perf_counter()
        iters = 0
        try:
            while iters < max_iters:
                iters += 1
                # --- joins (FCFS): dense is capped by slot count alone
                # (conservative memory mgmt); paged additionally requires the
                # request's (L_i + S) envelope to fit in free pages — the cap
                # becomes the *actual* free memory
                for s_i, s in enumerate(slots):
                    if s.req_idx < 0 and waiting:
                        ridx = waiting[0]
                        if self.kv_layout == "paged":
                            need = self._tokens_needed(
                                len(prompts[ridx]), min(forced[ridx], max_gen))
                            if not self.alloc.can_reserve(need):
                                break  # FCFS: head of line waits for pages
                            pages = self.alloc.reserve(ridx, need)
                            waiting.pop(0)
                            first, base = self._insert_paged(s_i, prompts[ridx],
                                                             pages)
                        else:
                            waiting.pop(0)
                            first, base = self._insert(s_i, prompts[ridx])
                        s.req_idx = ridx
                        s.cached = len(prompts[ridx])
                        s.base = base
                        s.gen = 0
                        s.cur = first
                        s.forced = min(forced[ridx], max_gen)
                        join_order.append(ridx)
                active = [s for s in slots if s.req_idx >= 0]
                if not active:
                    break
                concurrency.append(len(active))
                # --- one decode iteration over all slots (inactive rows masked)
                cur = np.zeros((self.max_slots,), np.int32)
                q_pos = np.zeros((self.max_slots,), np.int32)
                wslots = np.zeros((self.max_slots,), np.int32)
                for s_i, s in enumerate(slots):
                    if s.req_idx >= 0:
                        cur[s_i] = s.cur
                        q_pos[s_i] = s.cached + s.gen
                        wslots[s_i] = (s.base + s.gen) % self.W
                logits, self.cache = self._decode(self.params, self.cache,
                                                  jnp.asarray(cur), jnp.asarray(q_pos),
                                                  jnp.asarray(wslots))
                nxt = np.asarray(greedy(logits))
                for s_i, s in enumerate(slots):
                    if s.req_idx < 0:
                        continue
                    outputs[s.req_idx].append(int(s.cur))
                    s.gen += 1
                    finished = (s.cur == self.eos_id) or (s.gen >= s.forced)
                    if finished:
                        if self.kv_layout == "paged":
                            self.alloc.release(s.req_idx)
                            self.cache = clear_row(self.cache, s_i)
                        s.req_idx = -1  # exit immediately; slot joins next iter
                    else:
                        s.cur = int(nxt[s_i])
        finally:
            if self.kv_layout == "paged":
                # unwind: a mid-iteration exception (or max_iters
                # exhaustion) must not strand in-flight envelopes in the
                # engine-owned pool — the allocator outlives this call,
                # so a stranded owner would wedge every later serve()
                for s_i, s in enumerate(slots):
                    if s.req_idx >= 0:
                        self.alloc.release(s.req_idx)
                        self.cache = clear_row(self.cache, s_i)
                        s.req_idx = -1
        wall = time.perf_counter() - t0
        return ContinuousResult(outputs, wall, iters, join_order, concurrency)


class ContinuousResult:
    def __init__(self, outputs, wall_time, iterations, join_order,
                 concurrency=None):
        self.outputs = outputs
        self.wall_time = wall_time
        self.iterations = iterations
        self.join_order = join_order
        self.concurrency = concurrency or []

    @property
    def peak_parallel(self) -> int:
        return max(self.concurrency, default=0)

    @property
    def mean_parallel(self) -> float:
        return float(np.mean(self.concurrency)) if self.concurrency else 0.0
