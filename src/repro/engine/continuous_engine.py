"""Continuous-batching (iteration-level) engine — the ILS baseline, real JAX.

Slot-based, DeepSpeed-FastGen-like semantics:
  * a fixed number of slots (= the conservative parallelism cap the paper
    criticizes);
  * at every iteration boundary, finished requests exit and waiting requests
    join (FCFS), each join paying its own prefill;
  * no padding or invalid tokens are ever generated.

Each slot owns a region of a shared KV cache; rows advance independently
via per-row write slots (models.transformer.decode_step_rowslots).
Dense-family models only (the baseline is evaluated on llama-family, as in
the paper where FastGen serves LLaMA2).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import bucket_len
from repro.engine.sampling import greedy
from repro.models import transformer
from repro.models.attention import KVCache, init_kv_cache
from repro.models.registry import Model


class _Slot:
    __slots__ = ("req_idx", "cached", "base", "gen", "cur", "forced")

    def __init__(self):
        self.req_idx = -1
        self.cached = 0
        self.base = 0  # padded prefill width: decode writes go at base + gen
        self.gen = 0
        self.cur = 0
        self.forced = 1 << 30


class ContinuousEngine:
    def __init__(self, model: Model, params, max_slots: int = 8,
                 max_context: int = 2048, eos_id: int = 1, pad_id: int = 0,
                 len_bucket: int = 16):
        assert model.cfg.family in ("dense",), "ILS engine: dense family only"
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.W = max_context
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.len_bucket = len_bucket
        cfg = model.cfg
        self.cache = init_kv_cache(cfg.n_layers, max_slots, self.W,
                                   cfg.n_kv_heads, cfg.head_dim, cfg.dtype)
        self._decode = jax.jit(
            lambda p, c, t, qp, sl: transformer.decode_step_rowslots(
                p, cfg, c, t, qp, sl))
        self._prefill = jax.jit(
            lambda p, t, l: transformer.prefill(p, cfg, t, l, self.W),
            static_argnums=())

    # ------------------------------------------------------------------
    def _insert(self, row: int, prompt: np.ndarray):
        """Returns (first_token, padded_prefill_width)."""
        L = bucket_len(len(prompt), self.len_bucket)
        toks = np.full((1, L), self.pad_id, np.int32)
        toks[0, L - len(prompt):] = prompt
        last_logits, single = self._prefill(self.params, jnp.asarray(toks),
                                            jnp.asarray([len(prompt)], np.int32))
        c = self.cache
        self.cache = KVCache(
            k=c.k.at[:, row].set(single.k[:, 0]),
            v=c.v.at[:, row].set(single.v[:, 0]),
            slot_pos=c.slot_pos.at[row].set(single.slot_pos[0]),
            write_idx=c.write_idx,
            lengths=c.lengths.at[row].set(len(prompt)),
        )
        return int(np.asarray(greedy(last_logits))[0]), L

    # ------------------------------------------------------------------
    def serve(self, prompts: Sequence[np.ndarray],
              forced_gen_lens: Optional[Sequence[int]] = None,
              max_gen: int = 1024, max_iters: int = 100000) -> "ContinuousResult":
        """Serve all prompts to completion with continuous batching."""
        n = len(prompts)
        forced = list(forced_gen_lens) if forced_gen_lens is not None else [1 << 30] * n
        waiting = list(range(n))
        slots = [_Slot() for _ in range(self.max_slots)]
        outputs: List[List[int]] = [[] for _ in range(n)]
        join_order: List[int] = []
        t0 = time.perf_counter()
        iters = 0
        while iters < max_iters:
            iters += 1
            # --- joins (FCFS, capped by slot count = conservative memory mgmt)
            for s_i, s in enumerate(slots):
                if s.req_idx < 0 and waiting:
                    ridx = waiting.pop(0)
                    first, base = self._insert(s_i, prompts[ridx])
                    s.req_idx = ridx
                    s.cached = len(prompts[ridx])
                    s.base = base
                    s.gen = 0
                    s.cur = first
                    s.forced = min(forced[ridx], max_gen)
                    join_order.append(ridx)
            active = [s for s in slots if s.req_idx >= 0]
            if not active:
                break
            # --- one decode iteration over all slots (inactive rows masked)
            cur = np.zeros((self.max_slots,), np.int32)
            q_pos = np.zeros((self.max_slots,), np.int32)
            wslots = np.zeros((self.max_slots,), np.int32)
            for s_i, s in enumerate(slots):
                if s.req_idx >= 0:
                    cur[s_i] = s.cur
                    q_pos[s_i] = s.cached + s.gen
                    wslots[s_i] = (s.base + s.gen) % self.W
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(cur), jnp.asarray(q_pos),
                                              jnp.asarray(wslots))
            nxt = np.asarray(greedy(logits))
            for s_i, s in enumerate(slots):
                if s.req_idx < 0:
                    continue
                outputs[s.req_idx].append(int(s.cur))
                s.gen += 1
                finished = (s.cur == self.eos_id) or (s.gen >= s.forced)
                if finished:
                    s.req_idx = -1  # exit immediately; slot joins next iter
                else:
                    s.cur = int(nxt[s_i])
        wall = time.perf_counter() - t0
        return ContinuousResult(outputs, wall, iters, join_order)


class ContinuousResult:
    def __init__(self, outputs, wall_time, iterations, join_order):
        self.outputs = outputs
        self.wall_time = wall_time
        self.iterations = iterations
        self.join_order = join_order
