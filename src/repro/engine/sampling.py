"""Token sampling for the serving engines."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits: jnp.ndarray, temperature: float = 1.0,
                       top_k: int = 0) -> jnp.ndarray:
    """Categorical sampling with optional top-k truncation."""
    logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
