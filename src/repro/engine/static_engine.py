"""Static-batching inference engine (real JAX execution).

Semantics follow the paper's §2.4 exactly:
  * batched prompts are left-padded to the (bucketed) batch input length;
  * the batch runs prefill once, then decodes for at most ``slice_len``
    iterations (SCLS) or until every request has produced EOS — completed
    requests keep generating *invalid* tokens while others finish, just like
    HF/DS static batching (these are counted and discarded);
  * serving ends early only when ALL requests are done (paper's
    early-return case, measured in Fig. 14b/20b).

Shape discipline (TPU adaptation, DESIGN.md §8): batch size is bucketed to
the next power of two and input length to a multiple of ``len_bucket``, so
each (N, L) bucket hits one compiled executable.  The KV cache is allocated
at exactly ``L + slice_len`` slots — the paper's memory model Eq. (5).

``forced_gen_lens`` emulates known EOS positions so controlled experiments
can replay traces with ground-truth generation lengths while still doing
every real FLOP; pass None to rely on the model's own EOS.

Persistent paged storage (``kv_layout="paged"``): the engine owns a real
``repro.kvcache`` page pool and per-request page state that survives
across ``serve_batch_paged`` calls.  A resumed request remaps its
retained prefix pages into the dispatched batch's block table and decodes
straight from its stored next token — the paper's §3.3 re-prefill becomes
a page-table remap, and only evicted requests (memory pressure, worker
migration) fall back to the classic prompt+generated re-prefill.  Layout:
logical slot == absolute position (no pad slots), so the same pages read
identically in any batch composition.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import bucket_len
from repro.engine.sampling import greedy
from repro.models.registry import Model


def _pow2_bucket(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


#: decode-stage block tables are bucketed to multiples of this many blocks
#: so a growing batch does not recompile every slice
NB_BUCKET = 4


# Forced-length sentinel: a per-row forced length at/above this means "no
# emulated EOS — decode until the model's own EOS token".  Shared protocol
# with repro.serving.backends.RealBackend; fits int32 with headroom.
EOS_DRIVEN = 1 << 30


class _Resident:
    """Per-request page state retained across slices (paged engine)."""

    __slots__ = ("n_tokens", "next_token", "stamp")

    def __init__(self, n_tokens: int, next_token: int, stamp: int):
        self.n_tokens = n_tokens      # tokens whose K/V live in pages
        self.next_token = next_token  # precomputed first token of the resume
        self.stamp = stamp            # LRU clock for evict-on-pressure


class StaticEngine:
    def __init__(self, model: Model, params, eos_id: int = 1,
                 pad_id: int = 0, len_bucket: int = 16,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None,
                 kv_layout: str = "dense", page_tokens: int = 16,
                 kv_pool_tokens: Optional[int] = None,
                 prefix_sharing: bool = True, attn_impl: str = "unfused"):
        self.model = model
        self.params = params
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.len_bucket = len_bucket
        self.extra_inputs = extra_inputs or {}
        if attn_impl not in ("unfused", "fused"):
            raise ValueError(f"unknown attn_impl {attn_impl!r}")
        # "fused" routes the paged path through the fused RoPE+page-write /
        # RoPE+append+attention kernels; "unfused" is the baseline
        self.attn_impl = attn_impl
        self._compiled: Dict[Tuple[int, int, int], object] = {}
        self.compile_seconds = 0.0
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        self.kv_layout = kv_layout
        self.allocator = None
        self.prefix_sharing = prefix_sharing and kv_layout == "paged"
        if kv_layout == "paged":
            from repro.kvcache import PageAllocator, PrefixIndex  # deferred import cycle
            cfg = model.cfg
            if cfg.family != "dense":
                raise ValueError("persistent paged StaticEngine: dense "
                                 f"family only, got {cfg.family!r}")
            if self.extra_inputs:
                raise ValueError("persistent paged StaticEngine does not "
                                 "take frontend extra_inputs")
            if kv_pool_tokens is None or kv_pool_tokens <= 0:
                raise ValueError("kv_layout='paged' needs kv_pool_tokens "
                                 "(the engine-owned page pool size)")
            if kv_pool_tokens % page_tokens:
                raise ValueError(f"kv_pool_tokens {kv_pool_tokens} must be "
                                 f"a multiple of page_tokens {page_tokens}")
            self.page_tokens = page_tokens
            self.allocator = PageAllocator(kv_pool_tokens // page_tokens,
                                           page_tokens)
            P = self.allocator.n_pages + 1  # + null page 0
            shape = (cfg.n_layers, P, page_tokens, cfg.n_kv_heads,
                     cfg.head_dim)
            self._k_pages = jnp.zeros(shape, cfg.dtype)
            self._v_pages = jnp.zeros(shape, cfg.dtype)
            self._resident: Dict[int, _Resident] = {}
            self._prefix = PrefixIndex(page_tokens)
            self._stamp = 0
            self.n_evictions = 0
            from repro.models import transformer as _tfm
            from repro.kvcache.paged import PagedKVCache as _PKV

            def _prefill_paged(params, tokens, lengths, k_pages, v_pages,
                               block_table):
                W = block_table.shape[1] * page_tokens
                cache = _PKV(k_pages, v_pages, block_table,
                             jnp.full((tokens.shape[0], W), -1, jnp.int32),
                             jnp.zeros((tokens.shape[0],), jnp.int32))
                logits, cache = _tfm.prefill_paged(params, cfg, tokens,
                                                   lengths, cache,
                                                   attn_impl=attn_impl)
                return greedy(logits), cache.k_pages, cache.v_pages

            def _prefill_tail(params, tokens, start, lengths, k_pages,
                              v_pages, block_table):
                W = block_table.shape[1] * page_tokens
                cache = _PKV(k_pages, v_pages, block_table,
                             jnp.full((tokens.shape[0], W), -1, jnp.int32),
                             jnp.zeros((tokens.shape[0],), jnp.int32))
                logits, cache = _tfm.prefill_tail_paged(params, cfg, tokens,
                                                        start, lengths, cache,
                                                        attn_impl=attn_impl)
                return greedy(logits), cache.k_pages, cache.v_pages

            # donate the pool buffers so XLA updates them in place (the
            # pool is sized to most of HBM; without donation every call
            # would hold two full copies).  CPU ignores donation and
            # warns, so only donate on accelerators.
            donate = (() if jax.default_backend() == "cpu" else (3, 4))
            self._prefill_paged = jax.jit(_prefill_paged,
                                          donate_argnums=donate)
            donate_t = (() if jax.default_backend() == "cpu" else (4, 5))
            self._prefill_tail_paged = jax.jit(_prefill_tail,
                                               donate_argnums=donate_t)

    # ------------------------------------------------------------------
    def _serve_fn(self, slice_len: int):
        model, eos = self.model, self.eos_id

        @jax.jit
        def serve(params, tokens, lengths, forced, extra):
            B = tokens.shape[0]
            batch = {"tokens": tokens, "lengths": lengths, **extra}
            cache_window = tokens.shape[1] + slice_len
            if model.cfg.family == "vlm" and "prefix_embeds" in extra:
                cache_window += extra["prefix_embeds"].shape[1]
            last_logits, cache = model.prefill(params, batch, cache_window)
            tok0 = greedy(last_logits)

            def cond(state):
                step, _, _, done, _ = state
                return (step < slice_len) & ~jnp.all(done)

            def body(state):
                step, cur, cache, done, out = state
                out = jax.lax.dynamic_update_slice_in_dim(
                    out, cur[:, None], step, axis=1)
                gen_count = step + 1
                done = done | (cur == eos) | (gen_count >= forced)
                logits, cache = model.decode_step(params, cache, cur, step)
                nxt = greedy(logits)
                return step + 1, nxt, cache, done, out

            out = jnp.full((B, slice_len), -1, jnp.int32)
            done0 = jnp.zeros((B,), bool)
            step, _, _, done, out = jax.lax.while_loop(
                cond, body, (jnp.asarray(0, jnp.int32), tok0, cache, done0, out))
            return out, step, done

        return serve

    def _get_compiled(self, slice_len: int):
        key = slice_len
        if key not in self._compiled:
            self._compiled[key] = self._serve_fn(slice_len)
        return self._compiled[key]

    # ------------------------------------------------------------------
    # persistent paged path (kv_layout="paged")
    # ------------------------------------------------------------------
    def _serve_paged_fn(self, slice_len: int):
        from repro.kvcache.paged import PagedKVCache
        from repro.models import transformer as tfm
        cfg, eos = self.model.cfg, self.eos_id
        attn_impl = self.attn_impl
        # pool buffers donated in place, as in _prefill_paged (CPU ignores
        # donation and warns, so only donate on accelerators)
        donate = (() if jax.default_backend() == "cpu" else (1, 2))

        @partial(jax.jit, donate_argnums=donate)
        def serve(params, k_pages, v_pages, block_table, slot_pos, row_len,
                  first_tok, forced):
            B = first_tok.shape[0]
            cache = PagedKVCache(k_pages, v_pages, block_table, slot_pos,
                                 row_len)

            def cond(state):
                step, _, _, done, _ = state
                return (step < slice_len) & ~jnp.all(done)

            def body(state):
                step, cur, cache, done, out = state
                out = jax.lax.dynamic_update_slice_in_dim(
                    out, cur[:, None], step, axis=1)
                gen_count = step + 1
                done = done | (cur == eos) | (gen_count >= forced)
                q_pos = row_len + step  # compact layout: slot == position
                logits, cache = tfm.decode_step_paged(params, cfg, cache,
                                                      cur, q_pos, q_pos,
                                                      attn_impl=attn_impl)
                nxt = greedy(logits)
                return step + 1, nxt, cache, done, out

            out = jnp.full((B, slice_len), -1, jnp.int32)
            done0 = jnp.zeros((B,), bool)
            step, nxt, cache, done, out = jax.lax.while_loop(
                cond, body,
                (jnp.asarray(0, jnp.int32), first_tok, cache, done0, out))
            return out, step, done, nxt, cache.k_pages, cache.v_pages

        return serve

    def _get_compiled_paged(self, slice_len: int):
        key = ("paged", slice_len)
        if key not in self._compiled:
            self._compiled[key] = self._serve_paged_fn(slice_len)
        return self._compiled[key]

    def _evict(self, rid: int) -> None:
        """Drop a request's retained pages — its next dispatch falls back
        to the classic §3.3 re-prefill (memory safety over retention)."""
        self._resident.pop(rid, None)
        self._prefix.remove(rid)
        self.allocator.release(rid, missing_ok=True)
        self.n_evictions += 1

    def _lru_parked(self, protected) -> Optional[int]:
        """Oldest resident request NOT in the currently dispatched batch."""
        victims = [(res.stamp, rid) for rid, res in self._resident.items()
                   if rid not in protected]
        return min(victims)[1] if victims else None

    def _extend_evicting(self, rid: int, need: int, protected) -> None:
        """``allocator.extend`` with the LRU evict-on-pressure loop;
        re-raises ``MemoryError`` when no parked victim remains."""
        while True:
            try:
                # grows the caller's reservation; serve_batch_paged unwinds
                # it on MemoryError and retention frees it later via
                # release_request/_evict
                self.allocator.extend(rid, need)  # repro: transfer(allocator-pairing) — caller-owned reservation
                return
            except MemoryError:
                victim = self._lru_parked(protected)
                if victim is None:
                    raise
                self._evict(victim)

    def release_request(self, rid: int) -> int:
        """Free a request's retained pages (finish / cancel / migration);
        an explicit no-op for unknown rids.  Returns pages freed."""
        if self.kv_layout != "paged":
            return 0
        self._resident.pop(rid, None)
        self._prefix.remove(rid)
        return self.allocator.release(rid, missing_ok=True)

    @property
    def retained_blocks(self) -> int:
        """Blocks currently held by retained/in-flight requests."""
        return self.allocator.used_blocks if self.allocator else 0

    def serve_batch_paged(self, prompts: Sequence[np.ndarray],
                          slice_len: int, rids: Sequence[int],
                          forced_gen_lens: Optional[Sequence[int]] = None,
                          already_generated: Optional[Sequence[Sequence[int]]] = None,
                          ) -> "ServeResult":
        """Serve one slice with persistent paged KV storage.

        Same §2.4 semantics and token stream as ``serve_batch``, but K/V
        live in the engine's page pool keyed by ``rids``:

          * a request whose pages are resident performs ZERO re-prefill —
            its retained prefix pages are remapped into the batch block
            table and decode resumes from its stored next token;
          * a non-resident request (first dispatch, evicted, migrated)
            prefills prompt + ``already_generated`` into freshly reserved
            pages — the classic §3.3 fallback, counted in
            ``ServeResult.reprefill_tokens``;
          * at slice end every surviving row is trimmed to exactly its
            resident tokens and retained; pages are freed only by
            ``release_request`` (finish/cancel) or evict-on-pressure.

        Memory safety is unchanged: each row's envelope is its exact
        ``resident + slice_len`` tokens (≤ the scheduler's Eq. 5 batch
        bound), and on pool pressure parked residents are evicted LRU —
        a ``MemoryError`` with no parked victim means the DP batcher
        violated its own no-OOM constraint, as in the slice-scoped mode.
        """
        if self.kv_layout != "paged":
            raise ValueError("serve_batch_paged needs kv_layout='paged'")
        pg = self.page_tokens
        B_raw = len(prompts)
        if len(rids) != B_raw:
            raise ValueError(f"{len(rids)} rids for {B_raw} prompts — page "
                             f"residency is keyed by rid, one per row")
        if B_raw == 0:
            raise ValueError("empty batch")
        eff: List[np.ndarray] = []
        prevs: List[list] = []
        for i, p in enumerate(prompts):
            prev = list(already_generated[i]) if already_generated else []
            prevs.append(prev)
            eff.append(np.concatenate([np.asarray(p, np.int32),
                                       np.asarray(prev, np.int32)])
                       if prev else np.asarray(p, np.int32))

        # --- capacity planning: extend residents, reserve the rest,
        # evicting parked requests LRU under pressure.  All-or-nothing:
        # if the batch cannot be satisfied even with every parked resident
        # evicted (the DP batcher violated its own bound), the rows already
        # granted in THIS call are unwound before re-raising — otherwise
        # their ownerless reservations would wedge the pool for those rids
        # (reserve would KeyError on retry, masking the real failure)
        batch_set = set(rids)
        is_resident = []
        fresh: List[int] = []               # reserved this call, no residency
        grown: List[Tuple[int, int]] = []   # (rid, resident tokens before)
        shared_start: Dict[int, int] = {}   # row index -> shared prefix tokens
        shared_blocks = 0
        try:
            for i, rid in enumerate(rids):
                res = self._resident.get(rid)
                if res is not None and res.n_tokens != len(eff[i]):
                    # stale residency (token stream advanced elsewhere):
                    # fall back to a fresh prefill rather than serve bad KV
                    self._evict(rid)
                    res = None
                hit_pages: List[int] = []
                if res is None and self.prefix_sharing:
                    # cross-request prefix join: take references on another
                    # resident's full pages matching this prompt's head and
                    # prefill only the novel tail.  At least one tail token
                    # must remain to produce the next-token logits.
                    hit_pages, _ = self._prefix.lookup(eff[i])
                    n_hit = min(len(hit_pages), (len(eff[i]) - 1) // pg)
                    hit_pages = hit_pages[:n_hit]
                need = (res.n_tokens if res else len(eff[i])) + slice_len
                if res is None and hit_pages:
                    # share never allocates; the tail extension does, with
                    # its own evict-on-pressure loop.  On MemoryError the
                    # rid is already in ``fresh`` so the outer unwind drops
                    # its shared references too.
                    # retained past this call by design (kv_retain=
                    # "request"): freed by release_request/_evict; the
                    # except MemoryError arm below unwinds rows granted
                    # in THIS call
                    self.allocator.share(rid, hit_pages)  # repro: transfer(allocator-pairing) — retention owns it
                    fresh.append(rid)
                    self._extend_evicting(rid, need, batch_set)
                    shared_start[i] = len(hit_pages) * pg
                    shared_blocks += len(hit_pages)
                else:
                    while True:
                        try:
                            if res is not None:
                                # both arms retained by design (see the
                                # share above): freed via release_request/
                                # _evict, unwound by the except MemoryError
                                # arm below
                                if self.allocator.extend(rid, need):  # repro: transfer(allocator-pairing) — retention owns it
                                    grown.append((rid, res.n_tokens))
                            else:
                                self.allocator.reserve(rid, need)  # repro: transfer(allocator-pairing) — see above
                                fresh.append(rid)
                            break
                        except MemoryError:
                            victim = self._lru_parked(batch_set)
                            if victim is None:
                                raise
                            self._evict(victim)
                is_resident.append(res is not None)
        except MemoryError:
            for rid in fresh:
                self.allocator.release(rid, missing_ok=True)
            for rid, n_before in grown:
                if rid in self._resident:  # not evicted meanwhile
                    self.allocator.shrink(rid, n_before)
            raise

        # --- stage A: paged prefill of the non-resident rows
        # (clock starts here, just before device work, mirroring
        # serve_batch — so retain-mode latency comparisons measure the
        # same quantity and exclude host-side allocator bookkeeping)
        t0 = time.perf_counter()
        t_prefill = 0.0  # every row resident -> no stage-A device call
        first = np.zeros((B_raw,), np.int32)
        row_len = np.zeros((B_raw,), np.int64)
        pads = [0] * B_raw
        reprefill = 0
        prefix_hit = sum(shared_start.values())
        pre_idx = [i for i in range(B_raw)
                   if not is_resident[i] and i not in shared_start]
        tail_idx = sorted(shared_start)
        L_pre = 0
        if pre_idx:
            max_eff = max(len(eff[i]) for i in pre_idx)
            L_pre = bucket_len(max_eff, self.len_bucket)
            Bp = _pow2_bucket(len(pre_idx))
            toks = np.full((Bp, L_pre), self.pad_id, np.int32)
            lens = np.ones((Bp,), np.int32)
            nb_p = -(-L_pre // pg)
            btp = np.zeros((Bp, nb_p), np.int32)
            for s, i in enumerate(pre_idx):
                e = eff[i]
                toks[s, L_pre - len(e):] = e
                lens[s] = len(e)
                pages = self.allocator.pages_of(rids[i])
                btp[s, :min(len(pages), nb_p)] = pages[:nb_p]
                if prevs[i]:  # re-prefill beyond the first (§3.3 overhead)
                    reprefill += len(e)
            tok0, self._k_pages, self._v_pages = self._prefill_paged(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                self._k_pages, self._v_pages, jnp.asarray(btp))
            tok0 = np.asarray(tok0)  # host transfer: blocks on stage A
            for s, i in enumerate(pre_idx):
                first[i] = int(tok0[s])
                row_len[i] = len(eff[i])
                pads[i] = L_pre - len(eff[i])
        # --- stage A': tail-only prefill of the prefix-sharing rows — the
        # shared head is a page-table remap, only the novel tail runs
        if tail_idx:
            max_tail = max(len(eff[i]) - shared_start[i] for i in tail_idx)
            T_t = bucket_len(max_tail, self.len_bucket)
            Bt = _pow2_bucket(len(tail_idx))
            toks_t = np.full((Bt, T_t), self.pad_id, np.int32)
            start_t = np.zeros((Bt,), np.int32)
            lens_t = np.zeros((Bt,), np.int32)
            nb_t = bucket_len(
                max(len(self.allocator.pages_of(rids[i])) for i in tail_idx),
                NB_BUCKET)
            btt = np.zeros((Bt, nb_t), np.int32)
            for s, i in enumerate(tail_idx):
                e, st = eff[i], shared_start[i]
                toks_t[s, T_t - (len(e) - st):] = e[st:]
                start_t[s] = st
                lens_t[s] = len(e)
                pages = self.allocator.pages_of(rids[i])
                btt[s, :min(len(pages), nb_t)] = pages[:nb_t]
                if prevs[i]:  # only the tail re-runs on a reschedule
                    reprefill += len(e) - st
            tokt, self._k_pages, self._v_pages = self._prefill_tail_paged(
                self.params, jnp.asarray(toks_t), jnp.asarray(start_t),
                jnp.asarray(lens_t), self._k_pages, self._v_pages,
                jnp.asarray(btt))
            tokt = np.asarray(tokt)  # host transfer: blocks on stage A'
            for s, i in enumerate(tail_idx):
                first[i] = int(tokt[s])
                row_len[i] = len(eff[i])
                pads[i] = T_t - (len(eff[i]) - shared_start[i])
        if pre_idx or tail_idx:
            t_prefill = time.perf_counter() - t0
        for i, rid in enumerate(rids):
            if is_resident[i]:
                res = self._resident[rid]
                first[i] = res.next_token
                row_len[i] = res.n_tokens

        # --- stage B: one decode slice over the whole batch through the
        # per-row block tables (remapped retained pages + fresh ones)
        from repro.kvcache.paged import batch_block_table, batch_slot_pos
        B = _pow2_bucket(B_raw)
        max_pages = max(len(self.allocator.pages_of(r)) for r in rids)
        nb = bucket_len(max_pages, NB_BUCKET)
        pages_rows = [self.allocator.pages_of(r) for r in rids] \
            + [[] for _ in range(B - B_raw)]
        bt = batch_block_table(pages_rows, nb)
        lens_full = row_len.tolist() + [0] * (B - B_raw)
        sp = batch_slot_pos(lens_full, nb, pg)
        first_full = np.concatenate(
            [first, np.full((B - B_raw,), self.pad_id, np.int32)])
        forced = self._forced_array(forced_gen_lens, B, B_raw)
        fn = self._get_compiled_paged(slice_len)
        out, steps, done, nxt, kp, vp = fn(
            self.params, self._k_pages, self._v_pages, jnp.asarray(bt),
            jnp.asarray(sp), jnp.asarray(np.asarray(lens_full, np.int32)),
            jnp.asarray(first_full), jnp.asarray(forced))
        self._k_pages, self._v_pages = kp, vp
        out = np.asarray(jax.block_until_ready(out))
        nxt = np.asarray(nxt)
        wall = time.perf_counter() - t0
        steps = int(steps)

        # --- retention: trim every row to its resident tokens; pages are
        # freed only via release_request (finish/cancel) or eviction
        results = self._assemble_results(out, steps, done, forced_gen_lens,
                                         pads)
        for i, rid in enumerate(rids):
            new_len = int(row_len[i]) + steps
            self._stamp += 1
            self._resident[rid] = _Resident(new_len, int(nxt[i]),
                                            self._stamp)
            self.allocator.shrink(rid, new_len)
            if self.prefix_sharing:
                # index the row's full pages for future prefix joins; the
                # resident stream is prompt+generated so far followed by
                # every token this slice fed the decoder (out rows)
                stream = np.concatenate([eff[i], out[i, :steps]])
                self._prefix.insert(rid, stream,
                                    self.allocator.pages_of(rid))
        L_rep = bucket_len(int(max(row_len)), self.len_bucket)
        return ServeResult(results=results, steps=steps, wall_time=wall,
                           batch_input_len=max(L_pre, L_rep),
                           batch_size=B_raw,
                           early_return=steps < slice_len,
                           reprefill_tokens=reprefill,
                           prefill_time=t_prefill,
                           prefix_hit_tokens=prefix_hit,
                           shared_blocks=shared_blocks)

    # ------------------------------------------------------------------
    def serve_batch(self, prompts: Sequence[np.ndarray], slice_len: int,
                    forced_gen_lens: Optional[Sequence[int]] = None,
                    already_generated: Optional[Sequence[Sequence[int]]] = None,
                    ) -> "ServeResult":
        """Serve one static batch for at most ``slice_len`` iterations.

        ``already_generated``: per-request previously generated tokens —
        SCLS reschedule re-prefills prompt+generated (paper §3.3 overhead).
        """
        B_raw = len(prompts)
        eff = []
        reprefill = 0
        for i, p in enumerate(prompts):
            prev = list(already_generated[i]) if already_generated else []
            if prev:  # §3.3: a reschedule re-prefills prompt + generated
                reprefill += len(p) + len(prev)
            eff.append(np.concatenate([np.asarray(p, np.int32),
                                       np.asarray(prev, np.int32)])
                       if prev else np.asarray(p, np.int32))
        lengths = np.array([len(e) for e in eff], np.int32)
        L = bucket_len(int(lengths.max()), self.len_bucket)
        B = _pow2_bucket(B_raw)
        tokens = np.full((B, L), self.pad_id, np.int32)
        for i, e in enumerate(eff):
            tokens[i, L - len(e):] = e  # left padding
        lengths_p = np.concatenate([lengths, np.ones(B - B_raw, np.int32)])
        forced = self._forced_array(forced_gen_lens, B, B_raw)
        extra = {k: self._pad_extra(v, B, B_raw) for k, v in self.extra_inputs.items()}

        fn = self._get_compiled(slice_len)
        t0 = time.perf_counter()
        out, steps, done = fn(self.params, jnp.asarray(tokens),
                              jnp.asarray(lengths_p), jnp.asarray(forced), extra)
        out = np.asarray(jax.block_until_ready(out))
        wall = time.perf_counter() - t0
        steps = int(steps)
        results = self._assemble_results(
            out, steps, done, forced_gen_lens,
            [L - int(lengths[i]) for i in range(B_raw)])
        return ServeResult(results=results, steps=steps, wall_time=wall,
                           batch_input_len=L, batch_size=B_raw,
                           early_return=steps < slice_len,
                           reprefill_tokens=reprefill)

    def _forced_array(self, forced_gen_lens: Optional[Sequence[int]],
                      B: int, B_raw: int) -> np.ndarray:
        """Per-row forced lengths padded to the bucketed batch size (pad
        rows get 1 so they finish immediately); None → EOS-driven rows."""
        if forced_gen_lens is None:
            return np.full((B,), EOS_DRIVEN, np.int32)
        return np.concatenate([np.asarray(forced_gen_lens, np.int32),
                               np.ones(B - B_raw, np.int32)])

    def _assemble_results(self, out: np.ndarray, steps: int, done,
                          forced_gen_lens: Optional[Sequence[int]],
                          pads: Sequence[int]) -> List[dict]:
        """Per-row slice outcomes, shared verbatim by the dense and the
        persistent-paged paths (their token-exactness is pinned on it):
        a forced length below the sentinel emulates a known EOS position;
        the sentinel (or no forced list) means EOS-driven — the model's
        own EOS token ends the row."""
        results = []
        for i, pad in enumerate(pads):
            toks = out[i, :steps]
            f = (int(forced_gen_lens[i]) if forced_gen_lens is not None
                 else EOS_DRIVEN)
            if f < EOS_DRIVEN:
                n_valid = min(f, steps)
            else:
                eos_pos = np.where(toks == self.eos_id)[0]
                n_valid = int(eos_pos[0]) + 1 if len(eos_pos) else steps
            results.append(dict(tokens=toks[:n_valid].tolist(),
                                n_valid=n_valid,
                                finished=n_valid < steps or bool(done[i]),
                                invalid=steps - n_valid,
                                pad=pad))
        return results

    @staticmethod
    def _pad_extra(v: np.ndarray, B: int, B_raw: int):
        if v.shape[0] == B:
            return jnp.asarray(v)
        reps = np.concatenate([v, np.repeat(v[-1:], B - B_raw, axis=0)], axis=0)
        return jnp.asarray(reps)


class ServeResult:
    def __init__(self, results: List[dict], steps: int, wall_time: float,
                 batch_input_len: int, batch_size: int, early_return: bool,
                 reprefill_tokens: int = 0,
                 prefill_time: Optional[float] = None,
                 prefix_hit_tokens: int = 0, shared_blocks: int = 0):
        self.results = results
        self.steps = steps
        self.wall_time = wall_time
        self.batch_input_len = batch_input_len
        self.batch_size = batch_size
        self.early_return = early_return
        #: tokens prefilled beyond each request's FIRST prefill this call —
        #: the paper's §3.3 rescheduling overhead, 0 for resumed residents
        #: on the persistent paged path
        self.reprefill_tokens = reprefill_tokens
        #: measured wall seconds of the prefill stage, when it runs as a
        #: separate device call (serve_batch_paged stage A; 0.0 when every
        #: row resumed resident).  None on the fused dense path, where
        #: prefill and decode share one jit call and cannot be attributed
        #: separately.  Feeds the trace's prefill/decode sub-spans
        #: (repro.obs); never read by the scheduler.
        self.prefill_time = prefill_time
        #: prompt tokens satisfied by a cross-request prefix-page join
        #: this call (their prefill became a page-table remap), and the
        #: number of pages those joins took references on
        self.prefix_hit_tokens = prefix_hit_tokens
        self.shared_blocks = shared_blocks
