"""Static-batching inference engine (real JAX execution).

Semantics follow the paper's §2.4 exactly:
  * batched prompts are left-padded to the (bucketed) batch input length;
  * the batch runs prefill once, then decodes for at most ``slice_len``
    iterations (SCLS) or until every request has produced EOS — completed
    requests keep generating *invalid* tokens while others finish, just like
    HF/DS static batching (these are counted and discarded);
  * serving ends early only when ALL requests are done (paper's
    early-return case, measured in Fig. 14b/20b).

Shape discipline (TPU adaptation, DESIGN.md §8): batch size is bucketed to
the next power of two and input length to a multiple of ``len_bucket``, so
each (N, L) bucket hits one compiled executable.  The KV cache is allocated
at exactly ``L + slice_len`` slots — the paper's memory model Eq. (5).

``forced_gen_lens`` emulates known EOS positions so controlled experiments
can replay traces with ground-truth generation lengths while still doing
every real FLOP; pass None to rely on the model's own EOS.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import bucket_len
from repro.engine.sampling import greedy
from repro.models.registry import Model


def _pow2_bucket(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# Forced-length sentinel: a per-row forced length at/above this means "no
# emulated EOS — decode until the model's own EOS token".  Shared protocol
# with repro.serving.backends.RealBackend; fits int32 with headroom.
EOS_DRIVEN = 1 << 30


class StaticEngine:
    def __init__(self, model: Model, params, eos_id: int = 1,
                 pad_id: int = 0, len_bucket: int = 16,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None):
        self.model = model
        self.params = params
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.len_bucket = len_bucket
        self.extra_inputs = extra_inputs or {}
        self._compiled: Dict[Tuple[int, int, int], object] = {}
        self.compile_seconds = 0.0

    # ------------------------------------------------------------------
    def _serve_fn(self, slice_len: int):
        model, eos = self.model, self.eos_id

        @jax.jit
        def serve(params, tokens, lengths, forced, extra):
            B = tokens.shape[0]
            batch = {"tokens": tokens, "lengths": lengths, **extra}
            cache_window = tokens.shape[1] + slice_len
            if model.cfg.family == "vlm" and "prefix_embeds" in extra:
                cache_window += extra["prefix_embeds"].shape[1]
            last_logits, cache = model.prefill(params, batch, cache_window)
            tok0 = greedy(last_logits)

            def cond(state):
                step, _, _, done, _ = state
                return (step < slice_len) & ~jnp.all(done)

            def body(state):
                step, cur, cache, done, out = state
                out = jax.lax.dynamic_update_slice_in_dim(
                    out, cur[:, None], step, axis=1)
                gen_count = step + 1
                done = done | (cur == eos) | (gen_count >= forced)
                logits, cache = model.decode_step(params, cache, cur, step)
                nxt = greedy(logits)
                return step + 1, nxt, cache, done, out

            out = jnp.full((B, slice_len), -1, jnp.int32)
            done0 = jnp.zeros((B,), bool)
            step, _, _, done, out = jax.lax.while_loop(
                cond, body, (jnp.asarray(0, jnp.int32), tok0, cache, done0, out))
            return out, step, done

        return serve

    def _get_compiled(self, slice_len: int):
        key = slice_len
        if key not in self._compiled:
            self._compiled[key] = self._serve_fn(slice_len)
        return self._compiled[key]

    # ------------------------------------------------------------------
    def serve_batch(self, prompts: Sequence[np.ndarray], slice_len: int,
                    forced_gen_lens: Optional[Sequence[int]] = None,
                    already_generated: Optional[Sequence[Sequence[int]]] = None,
                    ) -> "ServeResult":
        """Serve one static batch for at most ``slice_len`` iterations.

        ``already_generated``: per-request previously generated tokens —
        SCLS reschedule re-prefills prompt+generated (paper §3.3 overhead).
        """
        B_raw = len(prompts)
        eff = []
        for i, p in enumerate(prompts):
            prev = list(already_generated[i]) if already_generated else []
            eff.append(np.concatenate([np.asarray(p, np.int32),
                                       np.asarray(prev, np.int32)])
                       if prev else np.asarray(p, np.int32))
        lengths = np.array([len(e) for e in eff], np.int32)
        L = bucket_len(int(lengths.max()), self.len_bucket)
        B = _pow2_bucket(B_raw)
        tokens = np.full((B, L), self.pad_id, np.int32)
        for i, e in enumerate(eff):
            tokens[i, L - len(e):] = e  # left padding
        lengths_p = np.concatenate([lengths, np.ones(B - B_raw, np.int32)])
        if forced_gen_lens is None:
            forced = np.full((B,), EOS_DRIVEN, np.int32)
        else:
            forced = np.concatenate([
                np.asarray(forced_gen_lens, np.int32),
                np.ones(B - B_raw, np.int32)])
        extra = {k: self._pad_extra(v, B, B_raw) for k, v in self.extra_inputs.items()}

        fn = self._get_compiled(slice_len)
        t0 = time.perf_counter()
        out, steps, done = fn(self.params, jnp.asarray(tokens),
                              jnp.asarray(lengths_p), jnp.asarray(forced), extra)
        out = np.asarray(jax.block_until_ready(out))
        wall = time.perf_counter() - t0
        steps = int(steps)
        results = []
        for i in range(B_raw):
            toks = out[i, :steps]
            # per-row semantics: a forced length below the sentinel emulates
            # a known EOS position; the sentinel (or no forced list) means
            # EOS-driven — the model's own EOS token ends the row
            f = (int(forced_gen_lens[i]) if forced_gen_lens is not None
                 else EOS_DRIVEN)
            if f < EOS_DRIVEN:
                n_valid = min(f, steps)
            else:
                eos_pos = np.where(toks == self.eos_id)[0]
                n_valid = int(eos_pos[0]) + 1 if len(eos_pos) else steps
            results.append(dict(tokens=toks[:n_valid].tolist(),
                                n_valid=n_valid,
                                finished=n_valid < steps or bool(done[i]),
                                invalid=steps - n_valid,
                                pad=L - int(lengths[i])))
        return ServeResult(results=results, steps=steps, wall_time=wall,
                           batch_input_len=L, batch_size=B_raw,
                           early_return=steps < slice_len)

    @staticmethod
    def _pad_extra(v: np.ndarray, B: int, B_raw: int):
        if v.shape[0] == B:
            return jnp.asarray(v)
        reps = np.concatenate([v, np.repeat(v[-1:], B - B_raw, axis=0)], axis=0)
        return jnp.asarray(reps)


class ServeResult:
    def __init__(self, results: List[dict], steps: int, wall_time: float,
                 batch_input_len: int, batch_size: int, early_return: bool):
        self.results = results
        self.steps = steps
        self.wall_time = wall_time
        self.batch_input_len = batch_input_len
        self.batch_size = batch_size
        self.early_return = early_return
