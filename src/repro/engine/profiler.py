"""One-time engine profiling → serving-time estimator fitting (paper §4.2).

Profiles T_prefill(N, L) and τ_decode(l, N) on the *real* JAX engine at a
grid of batch sizes / lengths, then fits Eq. 3/4 by least squares — exactly
the paper's methodology (scipy.curve_fit on a linear model ≡ lstsq).
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import ServingTimeEstimator
from repro.models.registry import Model


def profile_engine(model: Model, params, batch_sizes: Sequence[int],
                   input_lens: Sequence[int], n_decode_iters: int = 4,
                   repeats: int = 2, seed: int = 0
                   ) -> Tuple[List[tuple], List[tuple]]:
    """Returns (prefill_samples, decode_samples) of (N, L, seconds)."""
    rng = np.random.default_rng(seed)
    cfg = model.cfg
    prefill_samples, decode_samples = [], []

    for N in batch_sizes:
        for L in input_lens:
            toks = rng.integers(2, cfg.vocab_size, size=(N, L)).astype(np.int32)
            lengths = np.full((N,), L, np.int32)
            batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)}
            cache_window = L + n_decode_iters + 1

            prefill_j = jax.jit(lambda p, b: model.prefill(p, b, cache_window))
            decode_j = jax.jit(model.decode_step)
            # warmup (compile)
            last, cache = jax.block_until_ready(prefill_j(params, batch))
            best_p = np.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(prefill_j(params, batch))
                best_p = min(best_p, time.perf_counter() - t0)
            prefill_samples.append((N, L, best_p))

            cur = jnp.argmax(last, -1).astype(jnp.int32)
            jax.block_until_ready(decode_j(params, cache, cur, jnp.asarray(0, jnp.int32)))
            best_d = np.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                for s in range(n_decode_iters):
                    lg, cache = decode_j(params, cache, cur, jnp.asarray(s, jnp.int32))
                jax.block_until_ready(lg)
                best_d = min(best_d, (time.perf_counter() - t0) / n_decode_iters)
            # cached length ~ L (+ a few decode steps)
            decode_samples.append((N, L, best_d))
    return prefill_samples, decode_samples


def fit_estimator(model: Model, params, batch_sizes=(1, 2, 4), input_lens=(16, 32, 64),
                  bucket: int = 1, **kw) -> Tuple[ServingTimeEstimator, float, float]:
    pre, dec = profile_engine(model, params, batch_sizes, input_lens, **kw)
    return ServingTimeEstimator.fit(pre, dec, bucket=bucket)
