"""Real-execution cluster (legacy shim): the same scheduler code as the
simulator, but workers run batches on real JAX engines (StaticEngine),
every FLOP real.

The scheduling loop that used to live here moved into
``repro.serving.core.SchedulerCore``; this module keeps the historical
constructor working as a thin wrapper over ``SchedulerCore`` +
``repro.serving.backends.RealBackend``.  One physical CPU hosts all
workers, so each worker keeps a *virtual clock* advanced by the measured
wall time of its own batches — worker i's timeline is exactly what i
parallel machines would see.  Token outcomes (EOS, invalid, pads) come
from the engine, not from a latency model.

Prefer ``repro.serving.ServingConfig(...).build_real(engines, est, mem)``
for new code; it returns the online SliceServer API over the same core.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.cluster.metrics import RunMetrics
from repro.core.estimator import ServingTimeEstimator
from repro.core.memory import MemoryEstimator
from repro.core.request import Request
from repro.core.schedulers import StrategyConfig
from repro.engine.static_engine import StaticEngine
from repro.predict import LengthPredictor
from repro.serving.backends import RealBackend
from repro.serving.core import SchedulerCore


class RealCluster:
    """Deprecated shim: central-mode strategies (PM/AB/LB/SCLS and the
    prediction-aware SCLS-PRED/ORACLE) against real engines."""

    def __init__(self, strategy: StrategyConfig, engines: Sequence[StaticEngine],
                 sched_est: ServingTimeEstimator, mem: MemoryEstimator,
                 predictor: Optional[LengthPredictor] = None):
        assert strategy.mode in ("central", "pred")
        backend = RealBackend(engines, mem=mem, kv_layout=strategy.kv_layout,
                              sched_bucket=sched_est.bucket)
        self.engines = list(engines)
        self.core = SchedulerCore(strategy, backend, len(engines), sched_est,
                                  mem, predictor=predictor)

    # --- legacy attribute surface ---
    @property
    def s(self) -> StrategyConfig:
        return self.core.s

    @property
    def pred(self):
        return self.core.pred

    @property
    def predictor(self):
        return self.core.predictor

    @property
    def calibrator(self):
        return self.core.calibrator

    @property
    def allocators(self):
        return self.core.backend.allocators

    @property
    def batch_sizes(self) -> List[int]:
        return self.core.batch_sizes

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration: float) -> RunMetrics:
        return self.core.run(requests, duration)
