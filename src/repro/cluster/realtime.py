"""Real-execution cluster: the same scheduler code as the simulator, but
workers run batches on real JAX engines (StaticEngine), every FLOP real.

One physical CPU hosts all workers, so each worker keeps a *virtual clock*
advanced by the measured wall time of its own batches — worker i's timeline
is exactly what i parallel machines would see (scheduling decisions use
virtual time only).  Token outcomes (EOS, invalid, pads) come from the
engine, not from the latency model.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.metrics import RunMetrics, compute_metrics
from repro.core.batcher import dp_batch
from repro.core.estimator import ServingTimeEstimator
from repro.core.interval import next_interval
from repro.core.memory import MemoryEstimator, PagedMemoryEstimator
from repro.core.offloader import MaxMinOffloader, RoundRobinOffloader
from repro.core.request import Batch, Request
from repro.core.schedulers import StrategyConfig
from repro.engine.static_engine import StaticEngine
from repro.kvcache import PageAllocator
from repro.predict import LengthPredictor, PredictionPipeline


class RealCluster:
    """Central-mode strategies (PM/AB/LB/SCLS and the prediction-aware
    SCLS-PRED/ORACLE) against real engines."""

    def __init__(self, strategy: StrategyConfig, engines: Sequence[StaticEngine],
                 sched_est: ServingTimeEstimator, mem: MemoryEstimator,
                 predictor: Optional[LengthPredictor] = None):
        assert strategy.mode in ("central", "pred")
        self.s = strategy
        # pred mode: the shared pipeline (same code as the simulator)
        self.pred = (PredictionPipeline(strategy, predictor)
                     if strategy.mode == "pred" else None)
        self.predictor = self.pred.predictor if self.pred else None
        self.calibrator = self.pred.calibrator if self.pred else None
        self.engines = list(engines)
        self.n_workers = len(engines)
        self.est = sched_est
        self.mem = mem
        self.offloader = (MaxMinOffloader(self.n_workers)
                          if strategy.offload == "maxmin"
                          else RoundRobinOffloader(self.n_workers))
        # kv_layout="paged": each worker machine gets a real page allocator;
        # a scheduled slice reserves every member's (L_i + S) envelope at
        # slice start and frees it at slice end, so the DP batcher's no-OOM
        # constraint (block-counting fits()) is enforced by an actual free
        # list rather than assumed
        self.allocators: Optional[List[PageAllocator]] = None
        if strategy.kv_layout == "paged":
            if not isinstance(mem, PagedMemoryEstimator):
                raise TypeError("kv_layout='paged' needs a PagedMemoryEstimator")
            if mem.bucket % sched_est.bucket:
                # fits() admits with mem.bucket over raw lengths, while the
                # slice-start reserve charges the batch input length (est-
                # bucketed); mem.bucket must be a multiple of est.bucket so
                # admission is at least as conservative as the reserve —
                # otherwise a legitimately admitted batch can MemoryError
                raise ValueError(
                    f"PagedMemoryEstimator.bucket ({mem.bucket}) must be a "
                    f"multiple of the estimator bucket ({sched_est.bucket})")
            self.allocators = [PageAllocator(mem.total_blocks, mem.page_tokens)
                               for _ in self.engines]
        self.pool: List[Request] = []
        self.worker_time = [0.0] * self.n_workers
        self.worker_queue: List[List[Batch]] = [[] for _ in range(self.n_workers)]
        self.batch_sizes: List[int] = []
        self.early_returns = 0
        self.total_batches = 0
        self.generated_tokens: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------
    def _serve_on_worker(self, w: int, b: Batch, start_time: float) -> float:
        """Run batch b on engine w; returns completion (virtual) time."""
        eng = self.engines[w]
        prompts = [r.prompt for r in b.requests]
        prev = [self.generated_tokens.get(r.rid, []) for r in b.requests]
        forced = [r.remaining_gen for r in b.requests]
        alloc = self.allocators[w] if self.allocators is not None else None
        if alloc is not None:
            # slice start: every member holds the batch envelope L_i + S
            # (rows are padded to the batch input length, as the engine's
            # per-batch cache is) — MemoryError here means the DP batcher
            # violated its own no-OOM constraint
            for r in b.requests:
                alloc.reserve(r.rid, b.input_len + b.slice_len)
        res = eng.serve_batch(prompts, b.slice_len, forced_gen_lens=forced,
                              already_generated=prev)
        if alloc is not None:
            for r in b.requests:  # slice end: envelope freed for the next tick
                alloc.release(r.rid)
        t_done = start_time + res.wall_time
        self.total_batches += 1
        self.batch_sizes.append(b.size)
        if res.early_return:
            self.early_returns += 1
        for r, rr in zip(b.requests, res.results):
            r.n_schedules += 1
            r.pad_tokens += rr["pad"]
            r.invalid_tokens += rr["invalid"]
            r.generated += rr["n_valid"]
            self.generated_tokens.setdefault(r.rid, []).extend(rr["tokens"])
            if r.first_token_time is None:
                r.first_token_time = t_done
            if r.remaining_gen <= 0:
                r.done = True
                r.finish_time = t_done
                r.output_tokens = self.generated_tokens.pop(r.rid)
                # online-learning feedback on every completed request
                if self.pred is not None:
                    self.pred.on_complete(r)
            else:
                self.pool.append(r)
        self.offloader.on_batch_complete(w, b.est_time)
        return t_done

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration: float) -> RunMetrics:
        arrivals = sorted(requests, key=lambda r: r.arrival)
        now = 0.0
        idx = 0
        while True:
            # admit arrivals up to the current virtual time
            while idx < len(arrivals) and arrivals[idx].arrival <= now:
                self.pool.append(arrivals[idx])
                idx += 1
            if not self.pool and idx < len(arrivals):
                now = max(now, arrivals[idx].arrival)
                continue
            if not self.pool and idx >= len(arrivals):
                break
            # one scheduling round
            reqs, self.pool = self.pool, []
            if self.s.mode == "pred":
                batches = self.pred.batches(reqs, self.est, self.mem)
            else:
                batches = dp_batch(reqs, self.s.slice_len, self.est, self.mem,
                                   max_batch_size=self.s.dp_cap)
            for w, b in self.offloader.assign(batches):
                start = max(self.worker_time[w], now)
                self.worker_time[w] = self._serve_on_worker(w, b, start)
            if self.s.adaptive_interval:
                dt = next_interval(self.offloader.min_load(), self.s.lam, self.s.gamma)
            else:
                dt = self.s.gamma
            now += dt
        return compute_metrics(self.s.name, list(requests), duration,
                               self.worker_time, self.batch_sizes,
                               self.early_returns, self.total_batches)
