"""Workload generation: Poisson arrivals + generation-length distributions
matching the paper's Fig. 6 (CodeFuse / ShareGPT: the vast majority of
requests generate < 512 tokens, with a thin tail to the 1024 limit)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    # log-normal parameters for input and generation lengths
    input_mu: float
    input_sigma: float
    gen_mu: float
    gen_sigma: float
    max_input: int = 1024
    max_gen: int = 1024


# CodeFuse-like (Fig. 6a): code prompts are long-ish, generations mostly short
CODEFUSE = WorkloadSpec("codefuse", input_mu=5.3, input_sigma=0.9,
                        gen_mu=4.6, gen_sigma=1.0)
# ShareGPT-like (Fig. 6b): chattier, slightly longer generations
SHAREGPT = WorkloadSpec("sharegpt", input_mu=4.8, input_sigma=1.0,
                        gen_mu=5.0, gen_sigma=1.0)

WORKLOADS = {"codefuse": CODEFUSE, "sharegpt": SHAREGPT}


def _trunc_lognormal(rng, mu, sigma, lo, hi, size):
    x = rng.lognormal(mu, sigma, size=size)
    return np.clip(np.round(x), lo, hi).astype(int)


def generate_trace(rate: float, duration: float, spec: WorkloadSpec = CODEFUSE,
                   seed: int = 0, vocab_size: Optional[int] = None
                   ) -> List[Request]:
    """Poisson arrivals at ``rate`` req/s for ``duration`` seconds."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * duration)
    arrivals = np.sort(rng.uniform(0.0, duration, size=n))
    in_lens = _trunc_lognormal(rng, spec.input_mu, spec.input_sigma, 1, spec.max_input, n)
    gen_lens = _trunc_lognormal(rng, spec.gen_mu, spec.gen_sigma, 1, spec.max_gen, n)
    reqs = []
    for i in range(n):
        prompt = None
        if vocab_size is not None:
            prompt = rng.integers(0, vocab_size, size=int(in_lens[i])).astype(np.int32)
        reqs.append(Request(rid=i, arrival=float(arrivals[i]),
                            input_len=int(in_lens[i]), gen_len=int(gen_lens[i]),
                            max_gen=spec.max_gen, prompt=prompt))
    return reqs


def length_distribution_summary(reqs: List[Request]) -> dict:
    g = np.array([r.gen_len for r in reqs])
    return {
        "n": len(reqs),
        "gen_p50": float(np.percentile(g, 50)),
        "gen_p90": float(np.percentile(g, 90)),
        "gen_p99": float(np.percentile(g, 99)),
        "frac_lt_512": float(np.mean(g < 512)),
    }
