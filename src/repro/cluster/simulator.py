"""Discrete-event multi-worker serving simulator (legacy shim).

The scheduling loop that used to live here moved verbatim into
``repro.serving.core.SchedulerCore``; this module keeps the historical
constructor working as a thin wrapper over ``SchedulerCore`` +
``repro.serving.backends.SimBackend`` (ground-truth latency model,
optionally noisy, in virtual time).  Scheduling decisions are therefore
*bit-identical* to the real cluster's — there is one code path with two
backends, pinned by ``tests/test_serving.py``'s golden equivalence test.

Prefer ``repro.serving.ServingConfig(...).build_sim()`` for new code;
it returns the online :class:`~repro.serving.server.SliceServer` API
(submit / stream / cancel) over the same core.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.cluster.metrics import RunMetrics
from repro.core.estimator import ServingTimeEstimator
from repro.core.memory import MemoryEstimator
from repro.core.request import Request
from repro.core.schedulers import StrategyConfig
from repro.predict import LengthPredictor
from repro.serving.backends import SimBackend
from repro.serving.core import SchedulerCore


@dataclasses.dataclass
class SimResult:
    metrics: RunMetrics
    requests: List[Request]
    worker_completion: List[float]
    batch_sizes: List[int]


class ClusterSimulator:
    """Deprecated shim: offline ``run()`` over the shared SchedulerCore."""

    def __init__(self, strategy: StrategyConfig, n_workers: int,
                 true_lat: ServingTimeEstimator, sched_est: ServingTimeEstimator,
                 mem: MemoryEstimator, noise_sigma: float = 0.0, seed: int = 0,
                 ils_span: int = 32, predictor: Optional[LengthPredictor] = None):
        backend = SimBackend(true_lat, noise_sigma=noise_sigma, seed=seed)
        self.core = SchedulerCore(strategy, backend, n_workers, sched_est,
                                  mem, predictor=predictor, ils_span=ils_span)

    # --- legacy attribute surface (tests/benchmarks read these) ---
    @property
    def s(self) -> StrategyConfig:
        return self.core.s

    @property
    def workers(self):
        return self.core.workers

    @property
    def pool(self) -> List[Request]:
        return self.core.pool

    @property
    def pred(self):
        return self.core.pred

    @property
    def predictor(self):
        return self.core.predictor

    @property
    def calibrator(self):
        return self.core.calibrator

    @property
    def batch_sizes(self) -> List[int]:
        return self.core.batch_sizes

    @property
    def batch_log(self) -> List[list]:
        return self.core.batch_log

    @property
    def peak_parallel(self) -> int:
        return self.core.peak_parallel

    @property
    def now(self) -> float:
        return self.core.now

    def _more_work_expected(self) -> bool:
        return self.core._more_work_expected()

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration: float) -> SimResult:
        metrics = self.core.run(requests, duration)
        return SimResult(metrics, list(requests),
                         [w.completion_time for w in self.core.workers],
                         self.core.batch_sizes)
