"""Discrete-event multi-worker serving simulator.

Runs the *actual* scheduler code (DP batcher, max-min offloader, adaptive
interval) against workers whose serving time comes from a calibrated
ground-truth latency model (paper-scale experiments) — the same scheduler
code that ``repro.launch.serve`` drives against real JAX engines.

Worker modes mirror the strategy modes (core.schedulers):
  * perreq     — SLS/SO: requests round-robined on arrival; each worker runs
                 FCFS static batches of fixed size from its local queue.
  * central    — PM/AB/LB/SCLS: a central tick fetches the pool, batches,
                 and offloads whole batches to worker queues.
  * pred       — SCLS-PRED/ORACLE: central tick, but requests are bucketed
                 by calibrated *predicted* remaining length with per-batch
                 slice lengths (core.batcher.bucketed_pred_batch); every
                 completed request is fed back to the online predictor.
  * continuous — ILS: per-iteration join/exit with a conservative
                 parallelism cap (DeepSpeed-FastGen-like).

Ground truth vs. estimator: the scheduler consults ``sched_est`` (fit from
profiles); workers consume time from ``true_lat`` (optionally noisy), so
estimation error and its consequences are modeled faithfully.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batcher import dp_batch, fcfs_batch
from repro.core.estimator import ServingTimeEstimator
from repro.core.interval import next_interval
from repro.core.memory import MemoryEstimator, PagedMemoryEstimator
from repro.core.offloader import MaxMinOffloader, Offloader, RoundRobinOffloader
from repro.core.request import Batch, Request, bucket_len
from repro.core.schedulers import StrategyConfig
from repro.cluster.metrics import RunMetrics, compute_metrics
from repro.predict import LengthPredictor, PredictionPipeline


@dataclasses.dataclass
class SimResult:
    metrics: RunMetrics
    requests: List[Request]
    worker_completion: List[float]
    batch_sizes: List[int]


class _Worker:
    __slots__ = ("wid", "queue", "busy", "completion_time",
                 "running", "pending", "next_wake")

    def __init__(self, wid: int):
        self.wid = wid
        self.queue: deque = deque()       # batches (static modes)
        self.pending: deque = deque()     # requests (perreq/continuous)
        self.running: list = []  # [req, cached_len, lease_left, blocks] continuous mode
        self.busy = False
        self.completion_time = 0.0
        self.next_wake = None


class ClusterSimulator:
    def __init__(self, strategy: StrategyConfig, n_workers: int,
                 true_lat: ServingTimeEstimator, sched_est: ServingTimeEstimator,
                 mem: MemoryEstimator, noise_sigma: float = 0.0, seed: int = 0,
                 ils_span: int = 32, predictor: Optional[LengthPredictor] = None):
        self.s = strategy
        # pred mode: the shared pipeline (same code as the real cluster)
        self.pred = (PredictionPipeline(strategy, predictor)
                     if strategy.mode == "pred" else None)
        self.predictor = self.pred.predictor if self.pred else None
        self.calibrator = self.pred.calibrator if self.pred else None
        self.n_workers = n_workers
        self.true_lat = true_lat
        self.est = sched_est
        self.mem = mem
        self.rng = np.random.default_rng(seed)
        self.noise_sigma = noise_sigma
        self.ils_span = ils_span
        self.workers = [_Worker(w) for w in range(n_workers)]
        self.offloader: Offloader = (
            MaxMinOffloader(n_workers) if strategy.offload == "maxmin"
            else RoundRobinOffloader(n_workers))
        self.pool: List[Request] = []
        self._events: list = []
        self._seq = itertools.count()
        self._rr = 0
        self.batch_sizes: List[int] = []
        self.early_returns = 0
        self.total_batches = 0
        self.peak_parallel = 0  # max concurrent requests on one worker
        self._lease_est: Dict[int, float] = {}
        self.now = 0.0

    # ------------------------------------------------------------------
    def _noise(self) -> float:
        if self.noise_sigma <= 0:
            return 1.0
        return float(self.rng.lognormal(0.0, self.noise_sigma))

    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration: float) -> SimResult:
        for r in requests:
            self._push(r.arrival, "arrival", r)
        if self.s.mode in ("central", "cont_scls", "pred"):
            self._push(0.0, "tick", None)
        while self._events:
            self.now, _, kind, payload = heapq.heappop(self._events)
            getattr(self, f"_on_{kind}")(payload)
        wct = [w.completion_time for w in self.workers]
        metrics = compute_metrics(self.s.name, list(requests), duration, wct,
                                  self.batch_sizes, self.early_returns,
                                  self.total_batches)
        return SimResult(metrics, list(requests), wct, self.batch_sizes)

    # ------------------------------------------------------------------
    # event handlers
    # ------------------------------------------------------------------
    def _on_arrival(self, req: Request):
        if self.s.mode in ("central", "cont_scls", "pred"):
            self.pool.append(req)
        elif self.s.mode == "perreq":
            w = self.workers[self._rr]
            self._rr = (self._rr + 1) % self.n_workers
            w.pending.append(req)
            if not w.busy:
                self._start_static_fcfs(w)
        else:  # continuous
            w = self.workers[self._rr]
            self._rr = (self._rr + 1) % self.n_workers
            w.pending.append(req)
            if not w.busy:
                self._continuous_step(w)

    def _on_tick(self, _):
        reqs, self.pool = self.pool, []
        if reqs and self.s.mode == "cont_scls":
            # beyond-paper: max-min placement of S-token *leases*; the
            # worker itself is a continuous-batching engine, so the load a
            # lease adds is its MARGINAL cost (the N-proportional part of
            # Eq. 1-4), not the serial batch-of-one time
            singles = []
            for r in reqs:
                L = r.effective_input_len
                marginal = (self.est.t_serve(1, L, self.s.slice_len)
                            - self.est.t_serve(0, L, self.s.slice_len))
                self._lease_est[r.rid] = marginal
                singles.append(Batch(requests=[r], input_len=L,
                                     slice_len=self.s.slice_len,
                                     est_time=marginal))
            for w, b in self.offloader.assign(singles):
                wk = self.workers[w]
                wk.pending.append(b.requests[0])
                if not wk.busy:
                    self._continuous_step(wk)
        elif reqs and self.s.mode == "pred":
            # SCLS-PRED / ORACLE: calibrated predicted remaining-length
            # caps pick the buckets and per-batch slice lengths
            batches = self.pred.batches(reqs, self.est, self.mem)
            for w, b in self.offloader.assign(batches):
                wk = self.workers[w]
                wk.queue.append(b)
                if not wk.busy:
                    self._start_batch(wk)
        elif reqs:
            cap = self.s.dp_cap if self.s.dp_cap else None
            batches = dp_batch(reqs, self.s.slice_len, self.est, self.mem,
                               max_batch_size=cap)
            for w, b in self.offloader.assign(batches):
                wk = self.workers[w]
                wk.queue.append(b)
                if not wk.busy:
                    self._start_batch(wk)
        if self.s.adaptive_interval:
            dt = next_interval(self.offloader.min_load(), self.s.lam, self.s.gamma)
        else:
            dt = self.s.gamma
        if self._more_work_expected():
            self._push(self.now + dt, "tick", None)

    def _more_work_expected(self) -> bool:
        if self.pool:
            return True
        if any(e[2] == "arrival" for e in self._events):
            return True
        # pending/running cover continuous-mode workers whose admission is
        # momentarily blocked (busy alone would miss leased-out work)
        if any(w.queue or w.busy or w.pending or w.running
               for w in self.workers):
            return True
        return False

    def _feedback(self, req: Request) -> None:
        """Online-learning hook: every completed request trains the
        predictor and scores its latest calibrated prediction."""
        if self.pred is not None:
            self.pred.on_complete(req)

    # ------------------------------------------------------------------
    # static batch serving (perreq + central)
    # ------------------------------------------------------------------
    def _start_static_fcfs(self, w: _Worker):
        if not w.pending:
            return
        n = self.s.fixed_batch_size or len(w.pending)
        group = [w.pending.popleft() for _ in range(min(n, len(w.pending)))]
        L = max(r.effective_input_len for r in group)
        b = Batch(requests=group, input_len=bucket_len(L, self.est.bucket),
                  slice_len=self.s.slice_len)
        b.est_time = self.est.t_serve(b.size, b.input_len, self.s.slice_len)
        w.queue.append(b)
        self._start_batch(w)

    def _start_batch(self, w: _Worker):
        if w.busy or not w.queue:
            return
        b = w.queue.popleft()
        steps = min(b.slice_len, max(r.remaining_gen for r in b.requests))
        dur = self.true_lat.t_serve(b.size, b.input_len, steps) * self._noise()
        w.busy = True
        self._push(self.now + dur, "batch_done", (w.wid, b, steps))

    def _on_batch_done(self, payload):
        wid, b, steps = payload
        w = self.workers[wid]
        w.busy = False
        w.completion_time = self.now
        self.total_batches += 1
        self.batch_sizes.append(b.size)
        if steps < b.slice_len:
            self.early_returns += 1
        unfinished = []
        for r in b.requests:
            r.n_schedules += 1
            r.pad_tokens += b.input_len - r.effective_input_len
            gen_now = min(r.remaining_gen, steps)
            r.invalid_tokens += steps - gen_now
            r.generated += gen_now
            if r.first_token_time is None:
                r.first_token_time = self.now
            if r.remaining_gen <= 0:
                r.done = True
                r.finish_time = self.now
                self._feedback(r)
            else:
                unfinished.append(r)
        self.offloader.on_batch_complete(wid, b.est_time)
        if unfinished:
            if self.s.mode in ("central", "pred"):
                self.pool.extend(unfinished)
            else:  # SO: re-send round-robin
                for r in unfinished:
                    tgt = self.workers[self._rr]
                    self._rr = (self._rr + 1) % self.n_workers
                    tgt.pending.append(r)
                    if not tgt.busy:
                        self._start_static_fcfs(tgt)
        if self.s.mode == "perreq" and w.pending and not w.busy:
            self._start_static_fcfs(w)
        elif w.queue:
            self._start_batch(w)

    # ------------------------------------------------------------------
    # continuous batching (ILS)
    # ------------------------------------------------------------------
    def _block_charge(self, eff_len: int) -> int:
        """kv_layout="paged": blocks the joining request's envelope holds —
        the slice lease S for cont_scls, the length-blind worst case
        (max_gen remaining) for plain ILS.  Fixed for the request's stay,
        exactly like the real engine's join-time ``reserve``."""
        if self.s.kv_layout != "paged":
            return 0
        S = (self.s.slice_len if self.s.mode == "cont_scls"
             else self.s.max_gen)
        return self.mem.blocks_per_request(eff_len, S)

    def _ils_token_budget_ok(self, w: _Worker, newreq: Request) -> bool:
        if self.s.kv_layout == "paged":
            # block-granular admission (repro.kvcache): each running
            # request occupies exactly its reserved envelope rounded up to
            # pages; the join fits iff the worker's pool has free blocks
            assert isinstance(self.mem, PagedMemoryEstimator), \
                "kv_layout='paged' needs a PagedMemoryEstimator"
            used = sum(blocks for *_, blocks in w.running)
            charge = self._block_charge(newreq.effective_input_len)
            return used + charge <= self.mem.total_blocks
        budget = self.s.max_cached_tokens
        if budget is None and self.s.mode == "cont_scls":
            # slices bound per-request growth to eff_len + S, so the exact
            # memory budget applies (no conservative cap) — Eq. 5/9.
            # NOTE: this is the *idealized* fragmentation-free allocator;
            # kv_layout="paged" is the realizable version (block-rounded)
            if hasattr(self.mem, "m_available") and self.mem.delta_bytes > 0:
                budget = int(self.mem.zeta * self.mem.m_available
                             / self.mem.delta_bytes)
        if budget is None:
            return True
        tokens = sum(c + self.s.slice_len for _, c, _, _ in w.running)
        return tokens + newreq.effective_input_len + self.s.slice_len <= budget

    def _continuous_step(self, w: _Worker):
        """Advance worker w: admit joins, then run a span of iterations."""
        dur = 0.0
        # admit (FCFS) under the conservative parallelism cap
        lease = self.s.mode == "cont_scls"
        while (w.pending and len(w.running) < self.s.max_parallel
               and self._ils_token_budget_ok(w, w.pending[0])):
            r = w.pending.popleft()
            dur += self.true_lat.t_prefill(1, r.effective_input_len) * self._noise()
            r.n_schedules += 1
            w.running.append([r, r.effective_input_len,
                              self.s.slice_len if lease else (1 << 30),
                              self._block_charge(r.effective_input_len)])
        if not w.running:
            w.busy = False
            return
        w.busy = True
        span = min(self.ils_span,
                   min(min(r.remaining_gen, lease_left)
                       for r, _, lease_left, _ in w.running))
        span = max(span, 1)
        N = len(w.running)
        self.peak_parallel = max(self.peak_parallel, N)
        avg_len = float(np.mean([c for _, c, _, _ in w.running]))
        # Σ_{i=1..span} τ(avg+i, N) ≈ span · τ(avg + span/2, N)
        dur += span * self.true_lat.tau_decode(avg_len + span / 2.0, N) * self._noise()
        self._push(self.now + dur, "cont_done", (w.wid, span, N))

    def _on_cont_done(self, payload):
        wid, span, n_running = payload
        w = self.workers[wid]
        w.completion_time = self.now
        self.batch_sizes.append(n_running)
        self.total_batches += 1
        still = []
        expired = []
        for r, c, lease_left, blocks in w.running:
            r.generated += span
            lease_left -= span
            if r.first_token_time is None:
                r.first_token_time = self.now
            if r.remaining_gen <= 0:
                r.done = True
                r.finish_time = self.now
                self._feedback(r)
                self.offloader.on_batch_complete(
                    w.wid, self._lease_est.pop(r.rid, 0.0))
            elif lease_left <= 0:  # slice lease over -> back to the pool
                expired.append(r)
                self.offloader.on_batch_complete(
                    w.wid, self._lease_est.pop(r.rid, 0.0))
            else:
                still.append([r, c + span, lease_left, blocks])
        w.running = still
        if expired:
            self.pool.extend(expired)
        self._continuous_step(w)
