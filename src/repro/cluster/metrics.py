"""Run metrics matching the paper's evaluation (§5.1 Metrics + dive figures)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass
class RunMetrics:
    name: str
    duration: float
    n_requests: int
    n_completed: int
    throughput: float           # completed requests / s (paper Fig. 12 top)
    mean_response: float        # paper Fig. 12 middle
    p95_response: float         # paper Fig. 12 bottom
    ct_std: float               # STD of worker completion times (Fig. 17)
    avg_batch_size: float       # Fig. 13b
    avg_invalid_tokens: float   # Fig. 13a
    avg_pad_tokens: float       # Fig. 13c
    avg_schedules: float        # Fig. 14a (slice count)
    early_return_ratio: float   # Fig. 14b
    makespan: float

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def compute_metrics(name: str, requests: Sequence[Request], duration: float,
                    worker_completion_times: Sequence[float],
                    batch_sizes: Sequence[int],
                    early_returns: int, total_batches: int) -> RunMetrics:
    done = [r for r in requests if r.done and r.finish_time is not None]
    resp = np.array([r.response_time() for r in done]) if done else np.array([0.0])
    ct = np.array(list(worker_completion_times)) if worker_completion_times else np.array([0.0])
    bs = np.array(list(batch_sizes)) if batch_sizes else np.array([0.0])
    return RunMetrics(
        name=name,
        duration=duration,
        n_requests=len(requests),
        n_completed=len(done),
        throughput=len(done) / max(ct.max(), duration, 1e-9),
        mean_response=float(resp.mean()),
        p95_response=float(np.percentile(resp, 95)),
        ct_std=float(ct.std()),
        avg_batch_size=float(bs.mean()),
        avg_invalid_tokens=float(np.mean([r.invalid_tokens for r in requests])),
        avg_pad_tokens=float(np.mean([r.pad_tokens for r in requests])),
        avg_schedules=float(np.mean([r.n_schedules for r in requests])),
        early_return_ratio=early_returns / max(total_batches, 1),
        makespan=float(ct.max()),
    )
