"""Run metrics matching the paper's evaluation (§5.1 Metrics + dive figures)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass
class RunMetrics:
    name: str
    duration: float
    n_requests: int
    n_completed: int
    throughput: float           # completed requests / s (paper Fig. 12 top)
    mean_response: float        # paper Fig. 12 middle
    p50_response: float         # end-to-end latency percentiles (beyond
    p95_response: float         # paper Fig. 12 bottom: p95 only)
    p99_response: float
    ttft_mean: float            # time to first token (slice-granular:
    ttft_p95: float             # tokens materialize at slice boundaries)
    ct_std: float               # STD of worker completion times (Fig. 17)
    avg_batch_size: float       # Fig. 13b
    avg_invalid_tokens: float   # Fig. 13a
    avg_pad_tokens: float       # Fig. 13c
    avg_schedules: float        # Fig. 14a (slice count)
    early_return_ratio: float   # Fig. 14b
    makespan: float
    # --- online-serving columns (SLO-aware admission, PR 4) ---
    # defaulted so offline runs and pre-existing benchmark CSV schemas
    # stay valid: no admission layer -> 0 rejected, and with no deadlines
    # submitted every completion trivially attains its (absent) SLO
    n_rejected: int = 0         # shed by admission before any prefill
    slo_attainment: float = 1.0  # completed-with-deadline meeting it
    # per-reason shed counts (repro.serving.admission reason codes), so
    # benchmark CSVs report WHY goodput was protected: "memory" = the
    # prompt cannot fit worker memory even as a batch of one (Eq. 5–9
    # bound < 1), "deadline" = predicted completion (Eq. 1–4 service +
    # Eq. 10–11 queue delay) exceeds the request's SLO deadline
    n_rejected_memory: int = 0
    n_rejected_deadline: int = 0
    # --- §3.3 rescheduling overhead (persistent paged KV, PR 5) ---
    # tokens prefilled beyond each request's FIRST prefill, summed over the
    # run: the cost slice-level scheduling pays to reschedule.  The
    # kv_retain="request" real backend drives this to 0 for uninterrupted
    # requests (prefix pages survive, re-prefill becomes a page-table
    # remap); the sim backend reports the analytic dense cost.
    reprefill_tokens: int = 0
    # --- cross-request prefix sharing (COW paged KV, PR 7) ---
    # prompt tokens satisfied by a refcounted prefix-page join instead of
    # prefill (multi-turn sessions, shared system prompts), and the pages
    # those joins took references on.  0 everywhere except the real
    # kv_retain="request" backend with prefix sharing enabled.
    prefix_hit_tokens: int = 0
    shared_blocks: int = 0

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def compute_metrics(name: str, requests: Sequence[Request], duration: float,
                    worker_completion_times: Sequence[float],
                    batch_sizes: Sequence[int],
                    early_returns: int, total_batches: int,
                    n_rejected: int = 0,
                    reprefill_tokens: int = 0,
                    reject_reasons: Optional[Dict[str, int]] = None,
                    prefix_hit_tokens: int = 0,
                    shared_blocks: int = 0,
                    ) -> RunMetrics:
    done = [r for r in requests if r.done and r.finish_time is not None]
    # SLO attainment: of the completed requests that carried a deadline
    # (online submissions with slo_ms), the fraction that met it.  Shed
    # work is reported separately as n_rejected; deadline-less (offline /
    # best-effort) runs default to 1.0 so the column is always finite.
    with_slo = [r for r in done if r.deadline is not None]
    slo_attainment = (float(np.mean([r.finish_time <= r.deadline
                                     for r in with_slo]))
                      if with_slo else 1.0)
    # requests can be empty (an online server drained before any submit)
    per_req = (np.array([[r.invalid_tokens, r.pad_tokens, r.n_schedules]
                         for r in requests], float)
               if requests else np.zeros((1, 3)))
    resp = np.array([r.response_time() for r in done]) if done else np.array([0.0])
    ttft = np.array([r.first_token_time - r.arrival for r in done
                     if r.first_token_time is not None])
    if ttft.size == 0:
        ttft = np.array([0.0])
    ct = np.array(list(worker_completion_times)) if worker_completion_times else np.array([0.0])
    bs = np.array(list(batch_sizes)) if batch_sizes else np.array([0.0])
    return RunMetrics(
        name=name,
        duration=duration,
        n_requests=len(requests),
        n_completed=len(done),
        throughput=len(done) / max(ct.max(), duration, 1e-9),
        mean_response=float(resp.mean()),
        p50_response=float(np.percentile(resp, 50)),
        p95_response=float(np.percentile(resp, 95)),
        p99_response=float(np.percentile(resp, 99)),
        ttft_mean=float(ttft.mean()),
        ttft_p95=float(np.percentile(ttft, 95)),
        ct_std=float(ct.std()),
        avg_batch_size=float(bs.mean()),
        avg_invalid_tokens=float(per_req[:, 0].mean()),
        avg_pad_tokens=float(per_req[:, 1].mean()),
        avg_schedules=float(per_req[:, 2].mean()),
        early_return_ratio=early_returns / max(total_batches, 1),
        makespan=float(ct.max()),
        n_rejected=int(n_rejected),
        slo_attainment=slo_attainment,
        reprefill_tokens=int(reprefill_tokens),
        n_rejected_memory=int((reject_reasons or {}).get("memory", 0)),
        n_rejected_deadline=int((reject_reasons or {}).get("deadline", 0)),
        prefix_hit_tokens=int(prefix_hit_tokens),
        shared_blocks=int(shared_blocks),
    )
