"""AdamW + cosine schedule in raw JAX (no optax)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any     # first moment (params-shaped pytree)
    nu: Any     # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, n):
        mh, nh = m / b1c, n / b2c
        delta = mh / (jnp.sqrt(nh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
