"""Token data pipeline for the training driver.

Deterministic synthetic corpus (Zipfian token stream with local structure)
chunked into fixed-length sequences, plus an iterator with host-side
prefetch semantics.  Real deployments would swap ``SyntheticCorpus`` for a
file-backed source; the interface is the same.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.3

    def stream(self, n_tokens: int, offset: int = 0) -> np.ndarray:
        rng = np.random.default_rng(self.seed + offset)
        toks = rng.zipf(self.zipf_a, size=n_tokens) % (self.vocab_size - 2) + 2
        # weave in local bigram structure so the LM has something to learn
        rep = rng.random(n_tokens) < 0.15
        toks[1:][rep[1:]] = toks[:-1][rep[1:]]
        return toks.astype(np.int32)


class TokenBatcher:
    """Yields {tokens, loss_mask} batches of (B, T)."""

    def __init__(self, corpus: SyntheticCorpus, batch_size: int, seq_len: int,
                 start_step: int = 0):
        self.corpus = corpus
        self.B = batch_size
        self.T = seq_len
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        n = self.B * self.T
        toks = self.corpus.stream(n, offset=self.step).reshape(self.B, self.T)
        self.step += 1
        return {"tokens": toks,
                "loss_mask": np.ones((self.B, self.T - 1), np.float32)}

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
