"""``repro.fleet`` — multi-instance serving: a router over N instances.

The layer above ``repro.serving``: each instance is one full serving
stack (``serve --http-port`` — SchedulerCore, admission, KV pool, HTTP
front end), and the fleet router plays the paper's Eq. 10–11 load game
*one level up*, placing whole requests on instances the way the
offloader places batches on workers:

  * :mod:`repro.fleet.registry` — :class:`InstanceRegistry` polls each
    instance's ``/healthz`` placement-input vector into typed
    :class:`InstanceSnapshot` rows; join/drain/leave lifecycle and
    crash eviction;
  * :mod:`repro.fleet.placement` — the pluggable :class:`Placer`
    protocol with ``round_robin``, ``least_load``, and
    ``retention_affinity`` policies;
  * :mod:`repro.fleet.router` — :class:`FleetRouter`, the stdlib HTTP
    proxy (SSE passthrough, verbatim 429 ``Retry-After``, session
    pinning with override, exactly-once crash re-placement).

Launch with ``python -m repro.launch.route``; benchmark with
``python -m benchmarks.bench_fleet``.
"""
from repro.fleet.placement import (PLACERS, LeastLoadPlacer, Placement,
                                   PlacementRequest, Placer,
                                   RetentionAffinityPlacer,
                                   RoundRobinPlacer, imbalance, make_placer)
from repro.fleet.registry import (InstanceRecord, InstanceRegistry,
                                  InstanceSnapshot)
from repro.fleet.router import FleetRouter, NoInstanceAvailable

__all__ = [
    "FleetRouter", "NoInstanceAvailable",
    "InstanceRegistry", "InstanceRecord", "InstanceSnapshot",
    "Placer", "Placement", "PlacementRequest", "PLACERS",
    "RoundRobinPlacer", "LeastLoadPlacer", "RetentionAffinityPlacer",
    "make_placer", "imbalance",
]
