"""Placement policies for the fleet router — Eq. 10–11, one level up.

Inside one instance the paper's Alg. 2 assigns batches to workers by
Eq. 11 loads (``repro.core.offloader``): charge the serving-time
estimate on assignment, subtract it on completion, always pick the
min-load worker.  The fleet router plays the *same* game one level up,
with instances in place of workers and whole requests in place of
batches:

  * ``round_robin`` — the count-based baseline (``RoundRobinOffloader``
    one level up): blind to request size and instance load;
  * ``least_load`` — Eq. 11 one level up: instance load = the
    instance's own polled Eq. 10–11 ``queue_delay_est`` plus the cost of
    everything this router placed there that has not come back yet (the
    charge decays exactly like ``Offloader``: added on placement,
    subtracted on completion — never reset by polls, because a paced
    instance drains whole slices between polls and its point-in-time
    estimate misses work the router knows is outstanding); near-ties
    break toward the least *cumulative* work placed, so an idle fleet
    degrades to size-weighted rotation rather than piling onto the
    sorted-first instance;
  * ``retention_affinity`` — ``least_load`` with the PR 7
    ``MaxMinOffloader`` epsilon tiebreak one level up: a session turn
    *prefers* the instance whose pages hold its history (the pin) and
    only migrates when that instance's load exceeds the fleet minimum by
    more than ``epsilon × (request cost + migration cost)``, where the
    migration cost is the §3.3 re-prefill of the resident history the
    move would throw away.

The router has no per-request Eq. 1–4 estimator of its own, so request
cost is the coarse linearization ``(prompt + max_tokens) × token_time``
— one price constant converting token counts into the same seconds
currency as the instances' queue-delay estimates.  Any constant
balances; matching the profile's decode latency just keeps the polled
and charged terms commensurate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Sequence, Tuple

from repro.fleet.registry import InstanceSnapshot

__all__ = ["PlacementRequest", "Placement", "Placer", "RoundRobinPlacer",
           "LeastLoadPlacer", "RetentionAffinityPlacer", "PLACERS",
           "make_placer", "imbalance", "DEFAULT_TOKEN_TIME"]

#: coarse per-token price (seconds) converting request sizes into the
#: queue-delay currency — ballpark decode latency of the A100/13B profile
DEFAULT_TOKEN_TIME = 0.03


@dataclasses.dataclass(frozen=True)
class PlacementRequest:
    """What the router knows about a request at placement time."""

    rid: int                          # router-side request counter
    input_tokens: int                 # estimated prompt length
    max_tokens: int                   # requested generation budget
    session_id: Optional[int] = None
    pinned: Optional[str] = None      # instance holding the session's pages
    history_tokens: int = 0           # resident prefix a migration re-prefills


@dataclasses.dataclass(frozen=True)
class Placement:
    """One placement decision (feeds the router's audit record)."""

    instance: str
    policy: str
    loads: Tuple[Tuple[str, float], ...] = ()  # decision-time loads, sorted


class Placer(Protocol):
    """Pluggable placement policy (the router's offloader)."""

    name: str

    def place(self, candidates: Sequence[InstanceSnapshot],
              req: PlacementRequest) -> Placement:
        """Pick an instance for ``req``; ``candidates`` is non-empty and
        sorted by url (healthy, non-draining instances only)."""
        ...

    def observe(self, candidates: Sequence[InstanceSnapshot]) -> None:
        """Fresh registry poll: ``candidates`` is the current placeable
        set (lets a placer prune state for departed instances)."""
        ...

    def on_complete(self, instance: str, req: PlacementRequest) -> None:
        """The proxied request finished on ``instance``."""
        ...


class RoundRobinPlacer:
    """Count-based baseline: cycle the sorted candidate list."""

    name = "round_robin"

    def __init__(self) -> None:
        self._i = 0

    def place(self, candidates: Sequence[InstanceSnapshot],
              req: PlacementRequest) -> Placement:
        chosen = candidates[self._i % len(candidates)]
        self._i += 1
        return Placement(instance=chosen.instance, policy=self.name)

    def observe(self, candidates: Sequence[InstanceSnapshot]) -> None:
        pass

    def on_complete(self, instance: str, req: PlacementRequest) -> None:
        pass


class LeastLoadPlacer:
    """Eq. 11 one level up with Offloader-style charge decay."""

    name = "least_load"

    def __init__(self, token_time: float = DEFAULT_TOKEN_TIME):
        if token_time <= 0:
            raise ValueError(f"token_time must be positive, "
                             f"got {token_time}")
        self.token_time = float(token_time)
        self._charges: Dict[str, float] = {}
        # cumulative placed work (never decremented): the tie-breaker
        # when instantaneous loads agree — typically a drained fleet
        # where every charge has been released and every polled delay is
        # ~0.  Without it min() would park every idle-time arrival on
        # the sorted-first instance.
        self._totals: Dict[str, float] = {}

    # -- the load model -------------------------------------------------
    def estimate(self, req: PlacementRequest) -> float:
        """Coarse request cost in seconds (Eq. 1 linearized)."""
        return (req.input_tokens + req.max_tokens) * self.token_time

    def load(self, snap: InstanceSnapshot) -> float:
        """Polled Eq. 10–11 delay + this router's outstanding charges.

        The two terms may briefly overlap (a poll lands while charged
        work is running), which only makes a busy instance look busier —
        the conservative direction for balancing."""
        return snap.queue_delay_est + self._charges.get(snap.instance, 0.0)

    def loads(self, candidates: Sequence[InstanceSnapshot]
              ) -> Tuple[Tuple[str, float], ...]:
        return tuple((s.instance, round(self.load(s), 6))
                     for s in candidates)

    # -- Placer protocol ------------------------------------------------
    def _pick(self, candidates: Sequence[InstanceSnapshot]
              ) -> InstanceSnapshot:
        # near-ties (within ~1 ms of load) break on least cumulative
        # placed work, then sorted url — deterministic for a fixed
        # sequence, and an idle fleet degrades to size-weighted rotation
        # instead of collapsing onto the sorted-first instance
        return min(candidates,
                   key=lambda s: (round(self.load(s), 3),
                                  self._totals.get(s.instance, 0.0),
                                  s.instance))

    def place(self, candidates: Sequence[InstanceSnapshot],
              req: PlacementRequest) -> Placement:
        loads = self.loads(candidates)
        chosen = self._pick(candidates)
        self._charge(chosen.instance, self.estimate(req))
        return Placement(instance=chosen.instance, policy=self.name,
                         loads=loads)

    def observe(self, candidates: Sequence[InstanceSnapshot]) -> None:
        # charges persist across polls (released by on_complete, like
        # Offloader.on_batch_complete); a poll only prunes ledger rows
        # for instances that left the placeable set — their in-flight
        # work died or drained with them
        live = {snap.instance for snap in candidates}
        for url in list(self._charges):
            if url not in live:
                del self._charges[url]

    def on_complete(self, instance: str, req: PlacementRequest) -> None:
        # mirror Offloader.on_batch_complete one level up; clamp at zero
        # because an eviction may already have pruned the charge
        c = self._charges.get(instance, 0.0)
        if c > 0.0:
            self._charges[instance] = max(0.0, c - self.estimate(req))

    def _charge(self, instance: str, cost: float) -> None:
        self._charges[instance] = self._charges.get(instance, 0.0) + cost
        self._totals[instance] = self._totals.get(instance, 0.0) + cost


class RetentionAffinityPlacer(LeastLoadPlacer):
    """Least-load with the MaxMin epsilon tiebreak toward the instance
    retaining the session's pages (migration = §3.3 re-prefill)."""

    name = "retention_affinity"

    def __init__(self, token_time: float = DEFAULT_TOKEN_TIME,
                 epsilon: float = 0.25):
        super().__init__(token_time)
        if epsilon < 0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        self.epsilon = float(epsilon)

    def place(self, candidates: Sequence[InstanceSnapshot],
              req: PlacementRequest) -> Placement:
        loads = self.loads(candidates)
        chosen = self._pick(candidates)
        if req.pinned is not None and req.pinned != chosen.instance:
            pinned = next((s for s in candidates
                           if s.instance == req.pinned), None)
            if pinned is not None:
                # stay home unless the pinned instance is loaded more
                # than epsilon × (request cost + the re-prefill a move
                # would force) above the fleet minimum — the
                # MaxMinOffloader tiebreak with a migration-cost term
                slack = self.epsilon * (
                    self.estimate(req)
                    + req.history_tokens * self.token_time)
                if self.load(pinned) <= self.load(chosen) + slack:
                    chosen = pinned
        self._charge(chosen.instance, self.estimate(req))
        return Placement(instance=chosen.instance, policy=self.name,
                         loads=loads)


PLACERS: Tuple[str, ...] = ("round_robin", "least_load",
                            "retention_affinity")


def make_placer(name: str, *, token_time: float = DEFAULT_TOKEN_TIME,
                epsilon: float = 0.25) -> Placer:
    """Placer factory for CLI/router construction."""
    if name == "round_robin":
        return RoundRobinPlacer()
    if name == "least_load":
        return LeastLoadPlacer(token_time)
    if name == "retention_affinity":
        return RetentionAffinityPlacer(token_time, epsilon)
    raise ValueError(f"unknown placer {name!r}; choose from {PLACERS}")


def imbalance(served: Dict[str, int]) -> float:
    """max/min served-token imbalance across instances (the bench/fleet
    balance metric; 1.0 = perfectly even, inf when an instance idles)."""
    if not served:
        return 1.0
    lo, hi = min(served.values()), max(served.values())
    if lo <= 0:
        return float("inf") if hi > 0 else 1.0
    return hi / lo
