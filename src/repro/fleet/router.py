"""Fleet router: one HTTP front door over N serving instances.

Stdlib only, mirroring the PR 4 ``HTTPFrontend`` (``http.server`` +
threads); the router holds *no scheduler* — it polls instance
``/healthz`` into an :class:`~repro.fleet.registry.InstanceRegistry`,
places each request with a pluggable
:class:`~repro.fleet.placement.Placer`, and proxies the OpenAI surface:

  * ``POST /v1/completions`` / ``POST /v1/chat/completions`` — placed,
    then streamed through byte-for-byte: SSE chunks forward line by line
    as the instance emits them, a 429 forwards with the instance's
    ``Retry-After`` verbatim (the admission decision is the instance's
    to make, not the router's);
  * ``DELETE /v1/sessions/<id>`` — forwarded to the session's pinned
    instance; the pin and history bookkeeping drop with it;
  * ``GET /healthz`` — the fleet view: per-instance state rows,
    ``n_instances`` (registered), ``n_placeable``;
  * ``GET /metrics`` — the router's own Prometheus registry
    (placements, served tokens, re-prefill tokens, retries, evictions
    — all labeled per instance where it makes sense);
  * ``GET /metrics.json`` — one-shot JSON stats (what
    ``benchmarks/bench_fleet.py`` reads);
  * ``GET /debug/placements`` — the placement audit ring (per-decision
    policy, chosen instance, decision-time loads, migration info) —
    the fleet-level sibling of ``/debug/decisions``;
  * ``POST /fleet/join`` / ``/fleet/drain`` / ``/fleet/leave`` —
    instance lifecycle (see the registry module).

Sessions are **pinned with override**: a ``session`` turn prefers the
instance whose pages hold its history, but any policy (or a drain /
crash eviction) may place it elsewhere — the router then counts the
resident history as ``reprefill_tokens`` (§3.3: those prompt tokens
recompute on the new instance instead of joining shared pages).  The
accounting lives in the router, so it measures placement quality
identically over sim and real instances.

Crash handling is exactly-once: if the proxy connection to the placed
instance fails *before any response byte reached the client*, the
failure is noted in the registry (contributing to eviction) and the
request is re-placed **once** on the remaining instances.  Once bytes
have flowed, the router never resubmits — the client sees the truncated
stream and retries on its own terms (no duplicate generation).
"""
from __future__ import annotations

import json
import threading
import time
import urllib.error
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple, Union

from repro.fleet.placement import (DEFAULT_TOKEN_TIME, Placement,
                                   PlacementRequest, Placer, make_placer)
from repro.fleet.registry import InstanceRegistry
from repro.obs.audit import DecisionLog
from repro.obs.metrics import MetricsRegistry

__all__ = ["FleetRouter", "NoInstanceAvailable"]

#: request headers the proxy forwards upstream
_FORWARD_REQ_HEADERS = ("Content-Type",)
#: response headers the proxy forwards back verbatim
_FORWARD_RESP_HEADERS = ("Content-Type", "Retry-After")
_PROXY_PATHS = ("/v1/completions", "/v1/chat/completions")


class NoInstanceAvailable(RuntimeError):
    """No healthy, non-draining instance to place on (503 upstream)."""


class FleetRouter:
    """Route OpenAI-surface requests across instances — module docstring."""

    def __init__(self, instances: tuple = (), *,
                 placer: Union[str, Placer] = "retention_affinity",
                 host: str = "127.0.0.1", port: int = 0,
                 poll_interval: float = 1.0, poll_timeout: float = 2.0,
                 max_failures: int = 3, epsilon: float = 0.25,
                 token_time: float = DEFAULT_TOKEN_TIME,
                 audit_capacity: int = 1024,
                 request_timeout: float = 300.0):
        self.registry = InstanceRegistry(instances,
                                         poll_timeout=poll_timeout,
                                         max_failures=max_failures)
        self.placer: Placer = (make_placer(placer, token_time=token_time,
                                           epsilon=epsilon)
                               if isinstance(placer, str) else placer)
        self.poll_interval = float(poll_interval)
        self.request_timeout = float(request_timeout)
        self.audit = DecisionLog(max(1, audit_capacity))
        # placement + session state share one lock (handler threads)
        self._lock = threading.Lock()
        self._rid = 0
        self._sessions: Dict[int, str] = {}        # session -> pinned url
        self._session_tokens: Dict[int, int] = {}  # resident history est.
        self._served_tokens: Dict[str, int] = {}   # per-instance usage sum
        self._placements: Dict[str, int] = {}      # per-instance count
        self.reprefill_tokens = 0                  # migration-induced §3.3
        self._build_metrics()
        self.registry.on_evict(self._on_evict)
        self._httpd = ThreadingHTTPServer((host, port),
                                          self._handler_class())
        self.host, self.port = self._httpd.server_address[:2]
        self._http_thread: Optional[threading.Thread] = None
        self._started = False

    def _build_metrics(self) -> None:
        self.metrics = MetricsRegistry()
        self._m_requests = self.metrics.counter(
            "scls_fleet_requests", "Requests routed, by instance and "
            "outcome", labelnames=("instance", "code"))
        self._m_served = self.metrics.counter(
            "scls_fleet_served_tokens", "Prompt+completion tokens served "
            "per instance (from proxied usage)", labelnames=("instance",))
        self._m_reprefill = self.metrics.counter(
            "scls_fleet_reprefill_tokens", "Resident session history "
            "re-prefilled because a turn was placed off its pinned "
            "instance (migration cost, §3.3)")
        self._m_migrations = self.metrics.counter(
            "scls_fleet_session_migrations", "Session turns placed off "
            "their pinned instance")
        self._m_retries = self.metrics.counter(
            "scls_fleet_retries", "Requests re-placed after a proxy "
            "failure before first byte (exactly-once)")
        self._m_evictions = self.metrics.counter(
            "scls_fleet_evictions", "Instances evicted after consecutive "
            "poll/proxy failures")
        self._m_instances = self.metrics.gauge(
            "scls_fleet_instances", "Registered instances")

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "FleetRouter":
        if self._started:
            return self
        self._started = True
        # synchronous first poll: placement works before the first tick
        self.registry.poll_once()
        self.placer.observe(self.registry.placeable())
        self.registry.start(self.poll_interval)
        self._poll_observer_start()
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, name="fleet-router-listener",
            daemon=True)
        self._http_thread.start()
        return self

    def _poll_observer_start(self) -> None:
        """Feed each poll tick's placeable set to the placer (prunes
        charge-ledger rows for evicted/drained instances) and refresh
        the instance-count gauge."""
        self._observer_stop = threading.Event()

        def _loop() -> None:
            while not self._observer_stop.wait(self.poll_interval):
                with self._lock:
                    self.placer.observe(self.registry.placeable())
                self._m_instances.set(len(self.registry))

        self._observer_thread = threading.Thread(
            target=_loop, name="fleet-router-observe", daemon=True)
        self._observer_thread.start()

    def shutdown(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self.registry.stop()
        if self._started:
            self._observer_stop.set()
            self._observer_thread.join(timeout=5.0)
        if self._http_thread is not None:
            self._http_thread.join(timeout=5.0)

    def __enter__(self) -> "FleetRouter":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.shutdown()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _estimate_tokens(self, body: Dict[str, Any], chat: bool) -> int:
        """Prompt-size estimate for placement (whitespace words — the
        same pseudo-tokenization the instances use on strings)."""
        if chat:
            messages = body.get("messages")
            if isinstance(messages, list):
                return sum(len(str(m.get("content", "")).split())
                           for m in messages if isinstance(m, dict)) or 1
            return 1
        prompt = body.get("prompt")
        if isinstance(prompt, str):
            return len(prompt.split()) or 1
        if isinstance(prompt, int) and not isinstance(prompt, bool):
            return max(1, prompt)
        if isinstance(prompt, list):
            return max(1, len(prompt))
        return 1

    def _place(self, body: Dict[str, Any], chat: bool,
               exclude: Optional[str] = None
               ) -> Tuple[PlacementRequest, Placement]:
        """One placement decision under the router lock; raises
        :class:`NoInstanceAvailable` when the fleet has no candidate."""
        session = body.get("session") if chat else None
        if not (isinstance(session, int) and not isinstance(session, bool)
                and session > 0):
            session = None
        max_tokens = body.get("max_tokens", 16)
        if not isinstance(max_tokens, int) or isinstance(max_tokens, bool):
            max_tokens = 16
        input_tokens = self._estimate_tokens(body, chat)
        with self._lock:
            candidates = [s for s in self.registry.placeable()
                          if s.instance != exclude]
            if not candidates:
                raise NoInstanceAvailable(
                    "no healthy instance available for placement")
            self._rid += 1
            pinned = self._sessions.get(session) if session else None
            if pinned is not None and all(s.instance != pinned
                                          for s in candidates):
                pinned = None  # pin target drained/evicted: override
            preq = PlacementRequest(
                rid=self._rid, input_tokens=input_tokens,
                max_tokens=max(1, max_tokens), session_id=session,
                pinned=pinned,
                history_tokens=self._session_tokens.get(session, 0)
                if session else 0)
            placement = self.placer.place(candidates, preq)
            migrated = False
            reprefill = 0
            if session is not None:
                prev = self._sessions.get(session)
                if prev is not None and prev != placement.instance:
                    # pinned-with-override: the move re-prefills the
                    # resident history on the new instance (§3.3)
                    migrated = True
                    reprefill = self._session_tokens.get(session, 0)
                    self.reprefill_tokens += reprefill
                self._sessions[session] = placement.instance
            self._placements[placement.instance] = \
                self._placements.get(placement.instance, 0) + 1
            self.audit.record(
                "fleet_place", time.time(), rid=preq.rid,
                policy=placement.policy, instance=placement.instance,
                session=session, pinned=pinned, migrated=migrated,
                reprefill_tokens=reprefill,
                input_tokens=preq.input_tokens,
                max_tokens=preq.max_tokens,
                loads=dict(placement.loads),
                retried_from=exclude)
        if migrated:
            self._m_migrations.inc()
            self._m_reprefill.inc(reprefill)
        return preq, placement

    def _on_complete(self, instance: str, preq: PlacementRequest,
                     usage: Optional[Dict[str, Any]]) -> None:
        with self._lock:
            self.placer.on_complete(instance, preq)
            if usage is not None:
                total = usage.get("total_tokens")
                if isinstance(total, (int, float)) and total > 0:
                    self._served_tokens[instance] = \
                        self._served_tokens.get(instance, 0) + int(total)
                    self._m_served.inc(int(total), instance=instance)
                if preq.session_id is not None:
                    # the session's resident prefix after this turn: the
                    # whole rendered conversation so far
                    self._session_tokens[preq.session_id] = \
                        int(usage.get("total_tokens", 0))

    def _on_evict(self, url: str) -> None:
        """Crash eviction: unpin every session held there — the next
        turn re-places with a deliberate re-prefill."""
        with self._lock:
            stale = [sid for sid, inst in self._sessions.items()
                     if inst == url]
            for sid in stale:
                del self._sessions[sid]
            self.audit.record("fleet_evict", time.time(), instance=url,
                              unpinned_sessions=len(stale))
        self._m_evictions.inc()

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """The ``/metrics.json`` body (what ``bench_fleet`` reads)."""
        with self._lock:
            return dict(
                placer=self.placer.name,
                n_requests=self._rid,
                placements=dict(sorted(self._placements.items())),
                served_tokens=dict(sorted(self._served_tokens.items())),
                reprefill_tokens=self.reprefill_tokens,
                migrations=int(self._m_migrations.value()),
                retries=int(self._m_retries.value()),
                evictions=int(self._m_evictions.value()),
                sessions=len(self._sessions))

    def health(self) -> Dict[str, Any]:
        records = self.registry.records()
        with self._lock:
            n_sessions = len(self._sessions)
        return dict(
            status="ok", role="router", placer=self.placer.name,
            n_instances=len(records),
            n_placeable=sum(1 for r in records if r.placeable),
            instances=[r.summary() for r in records],
            sessions=n_sessions)

    # ------------------------------------------------------------------
    # proxying
    # ------------------------------------------------------------------
    def _open_upstream(self, instance: str, path: str, body: bytes,
                       headers: Dict[str, str],
                       method: str = "POST") -> Any:
        req = urllib.request.Request(f"{instance}{path}", data=body,
                                     headers=headers, method=method)
        # 4xx/5xx must forward verbatim, not raise — catch HTTPError,
        # which quacks like an HTTPResponse (.status/.headers/.read)
        try:
            return urllib.request.urlopen(req,
                                          timeout=self.request_timeout)
        except urllib.error.HTTPError as err:
            return err

    # ------------------------------------------------------------------
    # the handler class (closure over this router)
    # ------------------------------------------------------------------
    def _handler_class(self) -> type:
        router = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"
            server_version = "FleetRouter/1.0"

            def log_message(self, fmt: str, *args: Any) -> None:
                pass  # quiet CI logs (same as HTTPFrontend)

            # -- plumbing ----------------------------------------------
            def _json(self, code: int, obj: Dict[str, Any],
                      headers: Optional[Dict[str, str]] = None) -> None:
                payload = json.dumps(obj).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(payload)))
                for k, v in (headers or {}).items():
                    self.send_header(k, v)
                self.end_headers()
                self.wfile.write(payload)

            def _error(self, code: int, message: str, etype: str,
                       headers: Optional[Dict[str, str]] = None) -> None:
                self._json(code, {"error": {"message": message,
                                            "type": etype, "code": code}},
                           headers)

            def _read_body(self) -> bytes:
                n = int(self.headers.get("Content-Length") or 0)
                return self.rfile.read(n) if n > 0 else b""

            def _query_params(self) -> Dict[str, str]:
                parts = self.path.split("?", 1)
                if len(parts) == 1:
                    return {}
                return {k: v[-1] for k, v in
                        urllib.parse.parse_qs(parts[1]).items()}

            # -- routes -------------------------------------------------
            def do_GET(self) -> None:  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                if path == "/healthz":
                    self._json(200, router.health())
                elif path == "/metrics":
                    payload = router.metrics.render().encode()
                    self.send_response(200)
                    self.send_header(
                        "Content-Type",
                        "text/plain; version=0.0.4; charset=utf-8")
                    self.send_header("Content-Length", str(len(payload)))
                    self.end_headers()
                    self.wfile.write(payload)
                elif path == "/metrics.json":
                    self._json(200, router.stats())
                elif path == "/debug/placements":
                    q = self._query_params()
                    try:
                        limit = int(q["n"]) if "n" in q else None
                    except ValueError:
                        self._error(400, "n must be an integer",
                                    "invalid_request_error")
                        return
                    self._json(200, dict(
                        enabled=True, n_recorded=router.audit.n_recorded,
                        events=router.audit.query(kind=q.get("kind"),
                                                  limit=limit)))
                else:
                    self._error(404, f"no route {path}",
                                "invalid_request_error")

            def do_POST(self) -> None:  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                if path in _PROXY_PATHS:
                    self._proxy_completion(path)
                elif path in ("/fleet/join", "/fleet/drain",
                              "/fleet/leave"):
                    self._lifecycle(path)
                else:
                    self._error(404, f"no route {path}",
                                "invalid_request_error")

            def do_DELETE(self) -> None:  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                if not path.startswith("/v1/sessions/"):
                    self._error(404, f"no route {path}",
                                "invalid_request_error")
                    return
                try:
                    sid = int(path[len("/v1/sessions/"):])
                except ValueError:
                    self._error(400, "session id must be an integer",
                                "invalid_request_error")
                    return
                with router._lock:
                    pinned = router._sessions.pop(sid, None)
                    router._session_tokens.pop(sid, None)
                if pinned is None:
                    self._json(200, {"object": "session", "id": sid,
                                     "released": False})
                    return
                try:
                    resp = router._open_upstream(
                        pinned, path, b"", {}, method="DELETE")
                    resp.read()
                except OSError:
                    pass  # pin dropped either way; instance may be gone
                self._json(200, {"object": "session", "id": sid,
                                 "released": True})

            # -- instance lifecycle ------------------------------------
            def _lifecycle(self, path: str) -> None:
                try:
                    body = json.loads(self._read_body() or b"{}")
                    url = body["url"]
                except (json.JSONDecodeError, KeyError, TypeError):
                    self._error(400, "body must be JSON with a 'url'",
                                "invalid_request_error")
                    return
                try:
                    url = router.registry.normalize(url)
                except ValueError as e:
                    self._error(400, str(e), "invalid_request_error")
                    return
                if path == "/fleet/join":
                    router.registry.join(url)
                    ok = router.registry.poll_instance(url)
                    self._json(200, {"object": "fleet.join", "url": url,
                                     "healthy": ok})
                    return
                if path == "/fleet/drain":
                    known = router.registry.drain(url)
                else:  # /fleet/leave
                    known = router.registry.remove(url)
                    if known:
                        router._on_evict(url)  # unpin; count as removal
                if not known:
                    self._error(404, f"unknown instance {url}",
                                "invalid_request_error")
                    return
                if path == "/fleet/drain":
                    # draining stops placement but keeps the record; the
                    # placer must stop seeing it immediately
                    with router._lock:
                        router.placer.observe(router.registry.placeable())
                self._json(200, {"object": f"fleet.{path.rsplit('/')[-1]}",
                                 "url": url})

            # -- completion proxy --------------------------------------
            def _proxy_completion(self, path: str) -> None:
                raw = self._read_body()
                try:
                    body = json.loads(raw or b"")
                    if not isinstance(body, dict):
                        raise ValueError
                except ValueError:
                    self._error(400, "request body must be a JSON object",
                                "invalid_request_error")
                    return
                chat = path == "/v1/chat/completions"
                headers = {k: v for k in _FORWARD_REQ_HEADERS
                           if (v := self.headers.get(k))}
                headers.setdefault("Content-Type", "application/json")
                tried: Optional[str] = None
                for attempt in (0, 1):   # exactly one re-placement
                    try:
                        preq, placement = router._place(
                            body, chat, exclude=tried)
                    except NoInstanceAvailable as e:
                        self._error(503, str(e), "server_error",
                                    {"Retry-After": "1"})
                        return
                    try:
                        resp = router._open_upstream(
                            placement.instance, path, raw, headers)
                    except OSError:
                        # nothing reached the client yet: note the
                        # failure (counts toward eviction) and re-place
                        # once on the remaining instances
                        router.registry.note_failure(placement.instance)
                        tried = placement.instance
                        if attempt == 0:
                            router._m_retries.inc()
                            continue
                        self._error(502, "placed instance unreachable",
                                    "server_error")
                        return
                    self._forward(resp, placement.instance, preq)
                    return

            def _forward(self, resp: Any, instance: str,
                         preq: PlacementRequest) -> None:
                """Stream the upstream response through byte-faithfully;
                harvest usage for served-token accounting."""
                code = resp.status
                ctype = resp.headers.get("Content-Type", "")
                streaming = "text/event-stream" in ctype
                router._m_requests.inc(instance=instance, code=str(code))
                # accounting (charge release + served-token counters) must
                # land BEFORE the client can observe completion — a caller
                # that reads its response and then stats() must see this
                # request counted.  SSE: account when [DONE] arrives,
                # before forwarding it; non-stream: before the body write.
                usage: Optional[Dict[str, Any]] = None
                accounted = False
                if streaming:
                    self.send_response(code)
                    for k in _FORWARD_RESP_HEADERS:
                        v = resp.headers.get(k)
                        if v is not None:
                            self.send_header(k, v)
                    self.send_header("Cache-Control", "no-cache")
                    self.send_header("Connection", "close")
                    self.end_headers()
                    try:
                        while True:
                            line = resp.readline()
                            if not line:
                                break
                            if line.startswith(b"data: {"):
                                try:
                                    obj = json.loads(line[6:])
                                    usage = obj.get("usage") or usage
                                except ValueError:
                                    pass
                            elif (not accounted
                                  and line.startswith(b"data: [DONE]")):
                                router._on_complete(instance, preq, usage)
                                accounted = True
                            self.wfile.write(line)
                            if line == b"\n":
                                self.wfile.flush()
                    except (BrokenPipeError, ConnectionResetError):
                        pass  # client went away; instance cancels itself
                    finally:
                        resp.close()
                        if not accounted:   # truncated stream / no [DONE]
                            router._on_complete(instance, preq, usage)
                else:
                    payload = resp.read()
                    resp.close()
                    try:
                        obj = json.loads(payload)
                        if isinstance(obj, dict):
                            usage = obj.get("usage")
                    except ValueError:
                        pass
                    router._on_complete(instance, preq, usage)
                    self.send_response(code)
                    for k in _FORWARD_RESP_HEADERS:
                        v = resp.headers.get(k)
                        if v is not None:
                            self.send_header(k, v)
                    self.send_header("Content-Length",
                                     str(len(payload)))
                    self.end_headers()
                    self.wfile.write(payload)

        return Handler
