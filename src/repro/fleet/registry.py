"""Instance registry for the fleet router (``repro.fleet``).

One serving instance = one ``serve --http-port`` process (a full
``SchedulerCore`` with its own workers, admission controller, and KV
pool).  The registry is the router's *only* view of the fleet: it polls
each instance's ``/healthz`` — which exports the full placement-input
vector (the Eq. 10–11 load terms, free/retained/shared block counts,
resident session count; see ``HTTPFrontend._snapshot``) — into a typed
:class:`InstanceSnapshot` that the :class:`~repro.fleet.placement.Placer`
policies consume.

Lifecycle mirrors a real fleet:

  * ``join(url)`` — register a new instance (the router's ``POST
    /fleet/join`` endpoint lands here);
  * ``drain(url)`` — stop placing on it; already-proxied streams run on
    sockets the registry never touches, so they finish on their own;
  * ``remove(url)`` — drain + forget;
  * crash detection — a failed poll immediately marks the snapshot
    unhealthy (the placer skips it on the very next decision); after
    ``max_failures`` *consecutive* failures the instance is evicted and
    every ``on_evict`` callback fires (the router uses this to unpin
    sessions so their next turn re-places with a deliberate re-prefill).

Determinism: the registry holds no RNG and iterates instances in sorted
URL order everywhere, so a router driven by a fixed request sequence
against fixed snapshots makes a reproducible placement sequence (pinned
by ``tests/test_fleet.py``).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
import urllib.request
from typing import Any, Callable, Dict, List, Mapping, Optional

__all__ = ["InstanceSnapshot", "InstanceRecord", "InstanceRegistry"]

#: instance lifecycle states (``removed`` instances simply leave the map)
ACTIVE = "active"
DRAINING = "draining"


@dataclasses.dataclass(frozen=True)
class InstanceSnapshot:
    """One poll of one instance's ``/healthz`` — the placement inputs.

    ``queue_delay_est`` is the instance's own Eq. 10–11 predicted queue
    delay (``repro.serving.admission.predicted_queue_delay``): the
    min-load across its workers plus the backlog its pool would add.
    ``worker_loads`` / ``min_load`` are the raw Eq. 11 terms underneath
    it.  The block and session fields feed the ``retention_affinity``
    migration-cost term.
    """

    instance: str                    # registry key: the base URL
    healthy: bool
    polled_at: float                 # wall-clock time of the poll
    in_flight: int = 0               # live request handles
    queue_depth: int = 0             # queued + pending slices
    in_flight_slices: int = 0
    worker_loads: tuple = ()         # Eq. 11 per-worker loads (core s)
    min_load: float = 0.0            # Eq. 11 min over workers
    queue_delay_est: float = 0.0     # Eq. 10–11 predicted queue delay
    free_blocks: Optional[tuple] = None      # paged backend only
    retained_blocks: Optional[tuple] = None  # kv_retain=request only
    shared_blocks: int = 0           # COW prefix pages currently shared
    n_sessions: int = 0              # resident session anchors
    n_submitted: int = 0             # admission counters (cumulative)
    n_rejected: int = 0

    @classmethod
    def from_healthz(cls, instance: str, payload: Mapping[str, Any],
                     polled_at: float) -> "InstanceSnapshot":
        """Parse one ``/healthz`` body; absent keys keep their defaults
        (an older instance or a dense backend simply exports less)."""

        def _i(key: str, default: int = 0) -> int:
            v = payload.get(key, default)
            return int(v) if isinstance(v, (int, float)) else default

        def _f(key: str) -> float:
            v = payload.get(key, 0.0)
            return float(v) if isinstance(v, (int, float)) else 0.0

        def _blocks(key: str) -> Optional[tuple]:
            v = payload.get(key)
            return tuple(int(b) for b in v) if isinstance(v, list) else None

        loads = payload.get("worker_loads")
        return cls(
            instance=instance, healthy=payload.get("status") == "ok",
            polled_at=polled_at, in_flight=_i("in_flight"),
            queue_depth=_i("queue_depth"),
            in_flight_slices=_i("in_flight_slices"),
            worker_loads=(tuple(float(x) for x in loads)
                          if isinstance(loads, list) else ()),
            min_load=_f("min_load"), queue_delay_est=_f("queue_delay_est"),
            free_blocks=_blocks("free_blocks"),
            retained_blocks=_blocks("retained_blocks"),
            shared_blocks=_i("shared_blocks"), n_sessions=_i("n_sessions"),
            n_submitted=_i("n_submitted"), n_rejected=_i("n_rejected"))

    @classmethod
    def unreachable(cls, instance: str,
                    polled_at: float) -> "InstanceSnapshot":
        return cls(instance=instance, healthy=False, polled_at=polled_at)


@dataclasses.dataclass
class InstanceRecord:
    """Registry bookkeeping for one instance."""

    url: str
    state: str = ACTIVE              # ACTIVE | DRAINING
    snapshot: Optional[InstanceSnapshot] = None
    consecutive_failures: int = 0

    @property
    def placeable(self) -> bool:
        return (self.state == ACTIVE and self.snapshot is not None
                and self.snapshot.healthy)

    def summary(self) -> Dict[str, Any]:
        """The router's ``/healthz`` row for this instance."""
        out: Dict[str, Any] = dict(
            url=self.url, state=self.state,
            healthy=bool(self.snapshot and self.snapshot.healthy),
            consecutive_failures=self.consecutive_failures)
        if self.snapshot is not None and self.snapshot.healthy:
            out.update(queue_depth=self.snapshot.queue_depth,
                       in_flight=self.snapshot.in_flight,
                       queue_delay_est=self.snapshot.queue_delay_est,
                       n_sessions=self.snapshot.n_sessions)
        return out


class InstanceRegistry:
    """Polls instance ``/healthz`` into snapshots — module docstring.

    ``fetch`` is injectable for tests (``url -> healthz dict``, raising
    on an unreachable instance); the default issues a real HTTP GET.
    """

    def __init__(self, instances: tuple = (), *, poll_timeout: float = 2.0,
                 max_failures: int = 3,
                 fetch: Optional[Callable[[str], Mapping[str, Any]]] = None):
        if max_failures < 1:
            raise ValueError(f"max_failures must be >= 1, "
                             f"got {max_failures}")
        self.poll_timeout = float(poll_timeout)
        self.max_failures = int(max_failures)
        self._fetch = fetch if fetch is not None else self._fetch_healthz
        self._lock = threading.Lock()
        self._records: Dict[str, InstanceRecord] = {}
        self._on_evict: List[Callable[[str], None]] = []
        self._poll_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        for url in instances:
            self.join(url)

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    @staticmethod
    def normalize(url: str) -> str:
        url = url.strip().rstrip("/")
        if not url.startswith(("http://", "https://")):
            raise ValueError(f"instance url must be http(s), got {url!r}")
        return url

    def join(self, url: str) -> bool:
        """Register an instance; returns False if already present (a
        rejoin of a draining instance reactivates it)."""
        url = self.normalize(url)
        with self._lock:
            rec = self._records.get(url)
            if rec is not None:
                fresh = rec.state != ACTIVE
                rec.state = ACTIVE
                rec.consecutive_failures = 0
                return fresh
            self._records[url] = InstanceRecord(url=url)
            return True

    def drain(self, url: str) -> bool:
        """Stop placing on ``url``; in-flight proxied streams finish on
        their own sockets.  Returns False for an unknown instance."""
        url = self.normalize(url)
        with self._lock:
            rec = self._records.get(url)
            if rec is None:
                return False
            rec.state = DRAINING
            return True

    def remove(self, url: str) -> bool:
        url = self.normalize(url)
        with self._lock:
            return self._records.pop(url, None) is not None

    def on_evict(self, cb: Callable[[str], None]) -> None:
        """Register a crash-eviction callback (called with the url,
        outside the registry lock)."""
        self._on_evict.append(cb)

    # ------------------------------------------------------------------
    # views (always sorted by url — placement determinism)
    # ------------------------------------------------------------------
    def records(self) -> List[InstanceRecord]:
        with self._lock:
            return [self._records[u] for u in sorted(self._records)]

    def placeable(self) -> List[InstanceSnapshot]:
        """Healthy, non-draining snapshots in sorted-url order — the
        candidate list every placement decision sees."""
        with self._lock:
            return [r.snapshot for u, r in sorted(self._records.items())
                    if r.placeable and r.snapshot is not None]

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __contains__(self, url: str) -> bool:
        with self._lock:
            return self.normalize(url) in self._records

    # ------------------------------------------------------------------
    # polling / crash detection
    # ------------------------------------------------------------------
    def _fetch_healthz(self, url: str) -> Mapping[str, Any]:
        with urllib.request.urlopen(f"{url}/healthz",
                                    timeout=self.poll_timeout) as resp:
            payload = json.loads(resp.read())
        if not isinstance(payload, dict):
            raise ValueError(f"{url}/healthz returned non-object JSON")
        return payload

    def note_failure(self, url: str) -> bool:
        """One observed failure (poll *or* proxy) for ``url``; returns
        True when this failure crossed the eviction threshold."""
        url = self.normalize(url)
        evicted = False
        with self._lock:
            rec = self._records.get(url)
            if rec is None:
                return False
            rec.consecutive_failures += 1
            rec.snapshot = InstanceSnapshot.unreachable(url, time.time())
            if rec.consecutive_failures >= self.max_failures:
                del self._records[url]
                evicted = True
        if evicted:
            for cb in self._on_evict:
                cb(url)
        return evicted

    def poll_once(self) -> int:
        """Poll every registered instance once; returns the number of
        healthy snapshots.  Crash path: failures mark the snapshot
        unhealthy immediately and evict past ``max_failures``."""
        healthy = 0
        for url in sorted(u for u in self._urls()):
            try:
                payload = self._fetch(url)
            except Exception:
                self.note_failure(url)
                continue
            snap = InstanceSnapshot.from_healthz(url, payload, time.time())
            with self._lock:
                rec = self._records.get(url)
                if rec is None:  # removed while polling
                    continue
                rec.snapshot = snap
                rec.consecutive_failures = 0
            if snap.healthy:
                healthy += 1
        return healthy

    def _urls(self) -> List[str]:
        with self._lock:
            return list(self._records)

    def poll_instance(self, url: str) -> bool:
        """Poll a single instance now (used right after ``join`` so it
        becomes placeable without waiting for the next poll tick)."""
        url = self.normalize(url)
        try:
            payload = self._fetch(url)
        except Exception:
            self.note_failure(url)
            return False
        snap = InstanceSnapshot.from_healthz(url, payload, time.time())
        with self._lock:
            rec = self._records.get(url)
            if rec is None:
                return False
            rec.snapshot = snap
            rec.consecutive_failures = 0
        return snap.healthy

    # ------------------------------------------------------------------
    # background poll loop
    # ------------------------------------------------------------------
    def start(self, interval: float) -> None:
        if self._poll_thread is not None:
            return
        self._stop.clear()

        def _loop() -> None:
            while not self._stop.wait(interval):
                self.poll_once()

        self._poll_thread = threading.Thread(
            target=_loop, name="fleet-registry-poll", daemon=True)
        self._poll_thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._poll_thread is not None:
            self._poll_thread.join(timeout=5.0)
            self._poll_thread = None
