"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    """Axes that shard the batch: ("pod","data") multi-pod, ("data",) single."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
