"""Sharding rules: parameter, optimizer, batch, and cache PartitionSpecs.

Strategy (DESIGN.md §6): 2-D FSDP x TP —
  * weights: tensor-parallel on "model" (output dim for up-projections,
    input dim for down-projections, expert axis for MoE when divisible),
    plus FSDP on "data" over the first other divisible dim (so 22B-scale
    params and fp32 optimizer moments fit per device);
  * activations / caches: batch on ("pod","data"); KV heads on "model"
    when the head count divides, else replicated (MQA);
  * everything falls back to replication when sizes don't divide — the
    rules are pure shape arithmetic, so every assigned arch shards without
    per-arch tables.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

# parameter-name classes: which dim gets the "model" axis
_COL_PARALLEL = ("wq", "wk", "wv", "gate", "up", "k_up", "v_up", "w_in",
                 "w_gate", "lru_a", "lru_x", "in_proj", "wq_b")
_ROW_PARALLEL = ("wo", "down", "out_proj", "w_out")
_VOCAB_PARALLEL = ("table", "unembed")


def _axis_size(mesh, name: str) -> int:
    return mesh.devices.shape[mesh.axis_names.index(name)] if name in mesh.axis_names else 1


def _fsdp_extend(spec: list, shape: Tuple[int, ...], mesh, skip: set) -> list:
    """Add a "data" (FSDP) axis on the first divisible unsharded dim."""
    d = _axis_size(mesh, "data")
    if d == 1:
        return spec
    for i, s in enumerate(shape):
        if i in skip or spec[i] is not None:
            continue
        if s % d == 0 and s >= d:
            spec[i] = "data"
            return spec
    return spec


def param_pspec(path: str, leaf, mesh, cfg: ModelConfig,
                fsdp: bool = True, fsdp_min_bytes: int = 0) -> P:
    """PartitionSpec for one parameter leaf, from its path and shape.

    ``fsdp=False`` keeps weights TP-only (replicated over "data") — the
    right choice for *serving*, where there is no optimizer state and the
    per-step param all-gathers would dominate the collective roofline term
    (EXPERIMENTS.md §Perf iteration 3).  ``fsdp_min_bytes``: leave leaves
    smaller than this replicated (tiny models pay more in all-gather
    latency than they save in HBM — §Perf iteration 2)."""
    shape = leaf.shape
    m = _axis_size(mesh, "model")
    spec: list = [None] * len(shape)
    parts = path.split("/")
    name = parts[-2] if parts[-1] in ("w", "b") else parts[-1]
    is_bias = parts[-1] == "b"
    is_expert = "experts" in parts

    if len(shape) == 0:
        return P()
    if name in _VOCAB_PARALLEL or (name == "unembed" and not is_bias):
        # embed table (V, d) / unembed w (d, V): shard the vocab dim
        vdim = 0 if name == "table" else len(shape) - 1
        if shape[vdim] % m == 0:
            spec[vdim] = "model"
    elif is_expert and cfg.n_experts and cfg.n_experts % m == 0:
        # expert-parallel: the expert axis (first non-layer dim)
        edim = 1 if len(shape) >= 3 else 0  # (L, E, ...) stacked under scan
        if shape[edim] == cfg.n_experts:
            spec[edim] = "model"
    elif any(name == n for n in _COL_PARALLEL):
        d = len(shape) - 1
        if shape[d] % m == 0 and shape[d] >= m:
            spec[d] = "model"
    elif any(name == n for n in _ROW_PARALLEL) and not is_bias:
        d = len(shape) - 2
        if d >= 0 and shape[d] % m == 0 and shape[d] >= m:
            spec[d] = "model"
    # FSDP over "data" on another dim (weights >= 2D only; keep scalars/
    # norms replicated)
    import math
    nbytes = math.prod(shape) * getattr(leaf, "dtype", jnp.float32).itemsize \
        if hasattr(leaf, "dtype") else math.prod(shape) * 4
    if len(shape) >= 2 and fsdp and nbytes >= fsdp_min_bytes:
        spec = _fsdp_extend(spec, shape, mesh, skip=set())
    return P(*spec)


def tree_pspecs(tree, mesh, cfg: ModelConfig, prefix: str = "",
                fsdp: bool = True, fsdp_min_bytes: int = 0):
    """Map param_pspec over a pytree of arrays/ShapeDtypeStructs."""

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}{k}/") for k, v in node.items()}
        if hasattr(node, "_fields"):
            return type(node)(*(walk(getattr(node, f), f"{path}{f}/")
                                for f in node._fields))
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, f"{path}{i}/") for i, v in enumerate(node))
        return param_pspec(path, node, mesh, cfg, fsdp=fsdp,
                           fsdp_min_bytes=fsdp_min_bytes)

    return walk(tree, prefix)


# ---------------------------------------------------------------------------
# batch / activation shardings
# ---------------------------------------------------------------------------
def batch_pspec(batch_template: Dict[str, Any], mesh, global_batch: int
                ) -> Dict[str, P]:
    """Shard the leading batch dim over ("pod","data") when divisible."""
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    use_dp = tuple(dp) if global_batch % max(dp_size, 1) == 0 and dp_size > 1 else None

    out = {}
    for k, v in batch_template.items():
        nd = len(v.shape)
        if nd == 0:
            out[k] = P()
        elif use_dp is None:
            out[k] = P(*([None] * nd))
        else:
            out[k] = P(use_dp, *([None] * (nd - 1)))
    return out


def cache_pspec(cache_template, mesh, cfg: ModelConfig, global_batch: int):
    """PartitionSpecs for a serving cache pytree (KVCache/MLACache/Mamba/RG/
    MoE/EncDec).  Heuristic per leaf: shard the dim equal to the batch size
    over dp axes; shard a dim equal to n_kv_heads over "model" if it
    divides."""
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    m = _axis_size(mesh, "model")
    use_dp = tuple(dp) if global_batch % max(dp_size, 1) == 0 and dp_size > 1 else None

    def leaf_spec(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        batch_done = False
        for i, s in enumerate(shape):
            if not batch_done and s == global_batch and use_dp is not None:
                spec[i] = use_dp
                batch_done = True
            elif (s == cfg.n_kv_heads and cfg.n_kv_heads % m == 0
                  and cfg.n_kv_heads >= m and i >= len(shape) - 2):
                spec[i] = "model"
        return P(*spec)

    return jax.tree.map(leaf_spec, cache_template)


def named(tree_pspec, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspec,
                        is_leaf=lambda x: isinstance(x, P))
