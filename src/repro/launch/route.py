"""Fleet router launcher: one HTTP front door over N serve instances.

  # two instances + the router on one box (three terminals):
  PYTHONPATH=src python -m repro.launch.serve --backend sim \\
      --http-host 127.0.0.1 --http-port 8001 --time-scale 8 --duration 0
  PYTHONPATH=src python -m repro.launch.serve --backend sim \\
      --http-host 127.0.0.1 --http-port 8002 --time-scale 8 --duration 0
  PYTHONPATH=src python -m repro.launch.route --port 8000 \\
      --instance http://127.0.0.1:8001 --instance http://127.0.0.1:8002

  # clients talk to the router exactly as to a single instance:
  curl -s localhost:8000/v1/completions -H 'Content-Type: application/json' \\
      -d '{"prompt": "hello fleet", "max_tokens": 16}'

  # late instances join; drains stop placement but finish streams:
  curl -s localhost:8000/fleet/join -d '{"url": "http://127.0.0.1:8003"}'
  curl -s localhost:8000/fleet/drain -d '{"url": "http://127.0.0.1:8001"}'

Placement policies (``--placer``): ``round_robin`` (count baseline),
``least_load`` (the paper's Eq. 10–11 load signal one level up), and
``retention_affinity`` (default; least-load with an epsilon-bounded
preference for the instance retaining the request's session pages —
migrating a session costs its history in re-prefill tokens, §3.3).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

from repro.fleet import PLACERS, FleetRouter
from repro.fleet.placement import DEFAULT_TOKEN_TIME


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--instance", action="append", default=[],
                    metavar="URL",
                    help="serving instance base url (repeatable); more "
                         "can join later via POST /fleet/join")
    ap.add_argument("--placer", default="retention_affinity",
                    choices=list(PLACERS))
    ap.add_argument("--poll-interval", type=float, default=1.0,
                    help="seconds between /healthz polls of every "
                         "instance")
    ap.add_argument("--poll-timeout", type=float, default=2.0)
    ap.add_argument("--max-failures", type=int, default=3,
                    help="consecutive poll/proxy failures before an "
                         "instance is evicted")
    ap.add_argument("--epsilon", type=float, default=0.25,
                    help="retention_affinity load-slack factor (the "
                         "MaxMinOffloader tiebreak, one level up)")
    ap.add_argument("--token-time", type=float, default=DEFAULT_TOKEN_TIME,
                    help="router-side per-token cost estimate (seconds)")
    ap.add_argument("--duration", type=float, default=0.0,
                    help="seconds to serve (<= 0 = forever)")
    ap.add_argument("--audit-capacity", type=int, default=1024)
    args = ap.parse_args(argv)

    router = FleetRouter(
        tuple(args.instance), placer=args.placer, host=args.host,
        port=args.port, poll_interval=args.poll_interval,
        poll_timeout=args.poll_timeout, max_failures=args.max_failures,
        epsilon=args.epsilon, token_time=args.token_time,
        audit_capacity=args.audit_capacity)
    router.start()
    health = router.health()
    print(f"[route] fleet router listening on {router.url} "
          f"(placer={args.placer}, {health['n_placeable']}/"
          f"{health['n_instances']} instances placeable)", flush=True)
    try:
        if args.duration > 0:
            time.sleep(args.duration)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    stats = router.stats()
    router.shutdown()
    print(f"[route] routed {stats['n_requests']} requests "
          f"across {len(stats['placements'])} instances; "
          f"reprefill {stats['reprefill_tokens']} tokens, "
          f"{stats['retries']} retries, {stats['evictions']} evictions")
    print(json.dumps(stats, indent=2))


if __name__ == "__main__":
    main()
