"""Training launcher (the serving paper's substrate: every assigned arch is
trainable end-to-end, and the train_4k dry-run shape lowers this step).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import load_checkpoint, save_checkpoint
from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticCorpus, TokenBatcher
from repro.launch.steps import make_train_step
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig, init_adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"[train] {args.arch}: {n_params/1e6:.2f}M params, "
          f"B={args.batch} T={args.seq}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    opt = init_adamw(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    data = TokenBatcher(corpus, args.batch, args.seq)
    rng = np.random.default_rng(args.seed)

    losses = []
    t0 = time.time()
    for step, np_batch in zip(range(args.steps), data):
        batch = {"tokens": jnp.asarray(np_batch["tokens"])}
        if cfg.family == "encdec":
            batch["src_embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, 16, cfg.d_model)), cfg.dtype)
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.n_prefix_tokens, cfg.d_model)),
                cfg.dtype)
        params, opt, loss = step_fn(params, opt, batch)
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            rate = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:5d}  loss {float(loss):.4f}  tok/s {rate:,.0f}")

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt, step=args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
