"""train_step / serve_step builders shared by the trainer, server, dry-run."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update, init_adamw


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt = adamw_update(opt_cfg, grads, opt_state, params)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(model: Model, cache_window: int,
                      window: Optional[int] = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_window, window=window)

    return prefill_step


def make_decode_step(model: Model, window: Optional[int] = None):
    def decode_step(params, cache, tokens, step):
        return model.decode_step(params, cache, tokens, step, window=window)

    return decode_step
