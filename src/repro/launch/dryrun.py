import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers AND compiles on the production mesh, and extract the roofline terms.

The two lines above run before any other import (jax locks the device count
on first init); 512 placeholder host devices back the (2,16,16) multi-pod
mesh.  Nothing is ever allocated: inputs are ShapeDtypeStructs and we stop
at .lower().compile() + analyses.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out dryrun_results]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.shapes import InputShape, effective_window, token_specs
from repro.launch import sharding as shr
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.common import ModelConfig
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig, init_adamw

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

SLICE_LEN = 128  # SCLS slice length: prefill caches are L_i + S (Eq. 5)


def _key_spec():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def build_lowered(arch: str, shape_name: str, mesh, slice_len: int = SLICE_LEN,
                  cfg_override: Optional[ModelConfig] = None,
                  fsdp: bool = True, fsdp_min_bytes: int = 0,
                  seq_shard: bool = False):
    """Lower the right step for (arch, shape) on mesh. Returns (lowered, meta).

    Perf levers (EXPERIMENTS.md §Perf):
      fsdp=False       — TP-only weights (serving: no per-step param gathers)
      fsdp_min_bytes   — leave small leaves replicated (small models)
      seq_shard        — Megatron-SP: residual stream sequence-sharded over
                         the "model" axis between layers (train shapes)
    """
    from repro.models.common import set_activation_sharding
    from jax.sharding import PartitionSpec as P

    shape = SHAPES[shape_name]
    cfg = cfg_override or get_config(arch)
    if shape.kind == "train":
        cfg = cfg.replace(remat=True)
    window = effective_window(cfg, shape)
    model = get_model(cfg)

    if seq_shard and shape.seq_len % mesh.devices.shape[-1] == 0:
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        set_activation_sharding(P(dp, "model", None))
    else:
        set_activation_sharding(None)

    params_t = jax.eval_shape(model.init, _key_spec())
    params_ps = shr.tree_pspecs(params_t, mesh, cfg, fsdp=fsdp,
                                fsdp_min_bytes=fsdp_min_bytes)
    params_ns = shr.named(params_ps, mesh)

    batch_t = token_specs(cfg, shape)
    batch_ps = shr.batch_pspec(batch_t, mesh, shape.global_batch)
    batch_ns = shr.named(batch_ps, mesh)

    meta: Dict[str, Any] = dict(arch=arch, shape=shape_name, kind=shape.kind,
                                window=window, fsdp=fsdp, seq_shard=seq_shard,
                                fsdp_min_bytes=fsdp_min_bytes,
                                mesh=dict(zip(mesh.axis_names, mesh.devices.shape)))

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        opt_t = jax.eval_shape(init_adamw, params_t)
        opt_ps = shr.tree_pspecs(opt_t, mesh, cfg, fsdp=fsdp,
                                 fsdp_min_bytes=fsdp_min_bytes)
        opt_ns = shr.named(opt_ps, mesh)
        step = make_train_step(model, opt_cfg)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_ns, opt_ns, batch_ns),
                out_shardings=(params_ns, opt_ns, None),
                donate_argnums=(0, 1),
            ).lower(params_t, opt_t, batch_t)
        return lowered, meta

    if shape.kind == "prefill":
        cache_window = shape.seq_len + slice_len  # Eq. (5): L_i + S
        step = make_prefill_step(model, cache_window, window=window)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_ns, batch_ns),
            ).lower(params_t, batch_t)
        return lowered, meta

    # decode: one new token against a cache of seq_len
    if cfg.family in ("ssm", "hybrid"):
        cache_window = shape.seq_len  # constant state / ring handles it
    else:
        cache_window = shape.seq_len if window is None else min(shape.seq_len, window)
    prefill_T = cache_window
    pre_batch_t = dict(token_specs(cfg, shape))
    pre_batch_t["tokens"] = jax.ShapeDtypeStruct(
        (shape.global_batch, prefill_T), jnp.int32)
    cache_t = jax.eval_shape(
        lambda p, b: model.prefill(p, b, cache_window, window=window)[1],
        params_t, pre_batch_t)
    cache_ps = shr.cache_pspec(cache_t, mesh, cfg, shape.global_batch)
    cache_ns = shr.named(cache_ps, mesh)
    tok_t = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_ps = shr.batch_pspec({"t": tok_t}, mesh, shape.global_batch)["t"]
    step_t = jax.ShapeDtypeStruct((), jnp.int32)
    decode = make_decode_step(model, window=window)
    with mesh:
        lowered = jax.jit(
            decode,
            in_shardings=(params_ns, cache_ns, shr.named(tok_ps, mesh), None),
            out_shardings=(None, cache_ns),
            donate_argnums=(1,),
        ).lower(params_t, cache_t, tok_t, step_t)
    return lowered, meta


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6·N_active·D (training) or 2·N_active·D (single forward token batch)."""
    import math
    model = get_model(cfg)
    params_t = jax.eval_shape(model.init, _key_spec())
    n_params = sum(math.prod(x.shape) for x in jax.tree.leaves(params_t))
    n_active = n_params
    if cfg.n_experts:  # only top_k of n_experts experts run per token
        expert_p = 3 * cfg.d_model * cfg.d_ff_expert * (cfg.n_layers - cfg.first_dense_layers)
        n_active = n_params - expert_p * cfg.n_experts + expert_p * cfg.top_k
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * 1 * shape.global_batch  # decode: 1 token/request


def analyse(lowered, compiled, meta, n_chips: int) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    coll_bytes = sum(b for _, b in colls.values())
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    cfg = get_config(meta["arch"])
    shape = SHAPES[meta["shape"]]
    mf = model_flops(cfg, shape)
    terms = dict(
        compute_s=flops / PEAK_FLOPS,           # per-chip module flops
        memory_s=bytes_acc / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
    )
    dominant = max(terms, key=terms.get)
    return dict(
        **meta,
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll_bytes,
        collectives={k: dict(count=c, bytes=b) for k, (c, b) in colls.items()},
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        roofline=terms,
        dominant=dominant,
        model_flops_total=mf,
        model_flops_per_device=mf / n_chips,
        useful_flop_ratio=(mf / n_chips) / flops if flops else None,
    )


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            slice_len: int = SLICE_LEN, variant: str = "baseline",
            **build_kw) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered, meta = build_lowered(arch, shape_name, mesh, slice_len, **build_kw)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = analyse(lowered, compiled, meta, n_chips)
    rec.update(lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               n_chips=n_chips, multi_pod=multi_pod, status="ok",
               variant=variant)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        vtag = "" if variant == "baseline" else f"_{variant}"
        tag = (f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}{vtag}"
               ).replace("/", "_")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--slice-len", type=int, default=SLICE_LEN)
    ap.add_argument("--variant", default="baseline",
                    help="perf variant tag for the output file")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fsdp-min-mb", type=float, default=0.0)
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    combos = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
              else [(args.arch, args.shape)])
    ok = fail = 0
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, args.multi_pod, args.out, args.slice_len,
                          variant=args.variant, fsdp=not args.no_fsdp,
                          fsdp_min_bytes=int(args.fsdp_min_mb * 1e6),
                          seq_shard=args.seq_shard)
            r = rec["roofline"]
            print(f"OK   {arch:24s} {shape:12s} lower={rec['lower_s']:6.1f}s "
                  f"compile={rec['compile_s']:6.1f}s dom={rec['dominant']:12s} "
                  f"comp={r['compute_s']:.3e} mem={r['memory_s']:.3e} "
                  f"coll={r['collective_s']:.3e}", flush=True)
            ok += 1
        except Exception as e:
            print(f"FAIL {arch:24s} {shape:12s} {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
            fail += 1
    print(f"\n{ok} ok, {fail} failed")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
