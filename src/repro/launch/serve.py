"""Serving launcher: run the full SCLS stack through the online
``repro.serving`` API (SliceServer over one SchedulerCore).

  # real JAX engines (default): every token really computed
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --workers 2 --rate 2 --duration 15 --strategy scls

  # discrete-event sim backend (no model, CI smoke): same scheduler code
  PYTHONPATH=src python -m repro.launch.serve --backend sim --duration 3

  # observability (repro.obs): record a Perfetto-loadable Chrome trace of
  # the run (+ the scheduler decision audit next to it); with --http-port,
  # GET /metrics serves Prometheus text and /debug/decisions the audit
  PYTHONPATH=src python -m repro.launch.serve --backend sim --duration 3 \
      --trace-out trace.json

  # persistent paged KV storage: prefix pages survive across slices, so a
  # resumed slice re-prefills nothing (metrics: reprefill_tokens == 0 for
  # uninterrupted requests; --kv-retain slice restores §3.3 re-prefill)
  PYTHONPATH=src python -m repro.launch.serve --kv-layout paged \
      --kv-retain request --workers 1

  # prediction-aware scheduling (repro.predict): online histogram predictor
  PYTHONPATH=src python -m repro.launch.serve --strategy scls-pred \
      --predictor histogram --coverage 0.7

  # OpenAI-compatible HTTP endpoint with SLO-aware admission: concurrent
  # clients POST /v1/completions (stream=true -> SSE per slice), requests
  # predicted to miss --slo-ms get 429 + Retry-After before any prefill
  PYTHONPATH=src python -m repro.launch.serve --backend sim \
      --http-port 8000 --slo-ms 30000 --duration 0   # 0 = serve forever

The real backend profiles the engine, fits the Eq. 3/4 estimator, then
replays a Poisson trace through ``SliceServer`` — plus one *interactive*
request submitted mid-run, streamed per slice, to exercise the online
path (submit → tokens → result) a real deployment uses.  On a real TPU
cluster each worker becomes a mesh slice and the engine's jit functions
land on devices unchanged.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import sys

from repro.cluster.trace import WorkloadSpec, generate_trace
from repro.configs import ARCHS, get_config
from repro.serving import ServingConfig, SliceServer, default_sim_environment


def build_server(cfg: ServingConfig) -> tuple[SliceServer, int]:
    """(server, vocab_size) for the configured backend."""
    if cfg.backend == "sim":
        true_lat, est, mem = default_sim_environment(
            paged=cfg.kv_layout == "paged", page_tokens=cfg.page_tokens)
        return cfg.build_sim(true_lat, est, mem), 0

    import jax  # deferred: the sim path must not require a working model

    from repro.engine.profiler import fit_estimator
    from repro.engine.static_engine import StaticEngine
    from repro.models.registry import get_model

    if cfg.arch not in ARCHS:
        raise SystemExit(f"unknown --arch {cfg.arch!r}; choose from "
                         f"{sorted(ARCHS)}")
    arch = get_config(cfg.arch, reduced=cfg.reduced)
    if arch.family not in ("dense", "moe", "ssm", "hybrid"):
        raise SystemExit(f"serve launcher drives token-only archs; "
                         f"{cfg.arch} needs frontend embeddings (use examples/)")
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(cfg.seed))
    est, prmse, drmse = fit_estimator(model, params, batch_sizes=(1, 2, 4),
                                      input_lens=(16, 32, 64))
    print(f"[serve] estimator fitted: prefill rmse {prmse*1e3:.2f} ms, "
          f"decode rmse {drmse*1e3:.2f} ms")
    mem = cfg.memory_estimator(model.kv_bytes_per_token())
    if cfg.kv_layout == "paged":
        print(f"[serve] paged KV: {mem.total_blocks} blocks of "
              f"{cfg.page_tokens} tokens per worker "
              f"(kv_retain={cfg.kv_retain})")
    if cfg.kv_retain == "request":
        # persistent paged storage: each engine owns the page pool the
        # scheduler budgets, and prefix pages survive across slices
        if arch.family != "dense":
            raise SystemExit(f"--kv-retain request drives the persistent "
                             f"paged StaticEngine (dense family only); "
                             f"{cfg.arch} is {arch.family}")
        engines = [StaticEngine(model, params, eos_id=1, len_bucket=8,
                                kv_layout="paged",
                                page_tokens=cfg.page_tokens,
                                kv_pool_tokens=mem.total_blocks
                                * cfg.page_tokens,
                                prefix_sharing=cfg.prefix_sharing)
                   for _ in range(cfg.workers)]
        if cfg.prefix_sharing:
            print("[serve] COW prefix sharing on: matching prompt "
                  "prefixes join resident pages refcounted "
                  "(--no-prefix-sharing disables)")
    else:
        engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)
                   for _ in range(cfg.workers)]
    return cfg.build_real(engines, est, mem), arch.vocab_size


def serve_http(cfg: ServingConfig, server: SliceServer, vocab: int) -> None:
    """--http-port mode: expose the server over the OpenAI-compatible
    HTTP front end until --duration elapses (<= 0 = forever)."""
    import time

    from repro.serving import HTTPFrontend

    model_name = cfg.arch if cfg.backend == "real" else "scls-sim"
    front = HTTPFrontend(server.aio, host=cfg.http_host,
                         port=cfg.http_port, model_name=model_name,
                         vocab_size=vocab)
    front.start()
    print(f"[serve] http listening on {front.url} "
          f"(model={model_name}, slo_ms={cfg.slo_ms}, "
          f"time_scale={cfg.time_scale})", flush=True)
    try:
        if cfg.duration > 0:
            time.sleep(cfg.duration)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    front.shutdown(drain=True)
    m = server.metrics()
    stats = server.admission_stats
    print(f"[serve] http served {m.n_completed} completions "
          f"({stats['n_submitted']} submitted, {stats['n_rejected']} "
          f"rejected, {stats['n_degraded']} degraded); "
          f"SLO attainment {m.slo_attainment:.2f}")
    _export_trace(cfg, server)


def _export_trace(cfg: ServingConfig, server: SliceServer) -> None:
    """--trace-out: write the Chrome trace (+ the decision-audit dump
    alongside it) after the run."""
    if cfg.trace_out is None:
        return
    for path in server.core.obs.export(cfg.trace_out):
        print(f"[serve] wrote {path}")


def main() -> None:
    cfg = ServingConfig.from_cli(
        description=__doc__.splitlines()[0],
        backend="real", workers=2, slice_len=8, max_gen=24, gamma=0.25,
        rate=2.0, duration=15.0, mem_bucket=8)
    print(f"[serve] backend={cfg.backend} strategy={cfg.strategy} "
          f"workers={cfg.workers}"
          + (f" arch={cfg.arch} (reduced={cfg.reduced})"
             if cfg.backend == "real" else ""))
    server, vocab = build_server(cfg)

    if cfg.http_port is not None:
        serve_http(cfg, server, vocab)
        return

    spec = WorkloadSpec("demo", input_mu=3.0, input_sigma=0.7, gen_mu=2.3,
                        gen_sigma=0.7, max_input=64, max_gen=cfg.max_gen)
    trace = generate_trace(cfg.rate, cfg.duration, spec, seed=cfg.seed,
                           vocab_size=vocab or None)
    handles = server.replay(trace)

    # one interactive request through the online path: submit mid-run,
    # stream its tokens per slice, then read the finalized result
    import numpy as np
    rng = np.random.default_rng(cfg.seed + 1)
    prompt = (rng.integers(0, vocab, size=12).astype(np.int32)
              if vocab else None)
    live = server.submit(prompt, input_len=12, gen_len=min(10, cfg.max_gen),
                         max_gen=cfg.max_gen,
                         arrival=min(cfg.duration / 2, 1.0))
    streamed = list(itertools.islice(live.tokens(), 6))
    print(f"[serve] interactive rid={live.rid} streamed "
          f"{len(streamed)} tokens: {streamed}")
    live.result()

    metrics = server.drain(cfg.duration)
    _export_trace(cfg, server)
    print(json.dumps(dataclasses.asdict(metrics), indent=2))
    if server.core.predictor is not None:
        print(f"[serve] predictor={server.core.predictor.name} "
              f"calibration scale={server.core.calibrator.scale:.2f} "
              f"coverage={server.core.calibrator.empirical_coverage():.2f}")
    done = [h for h in handles if h.done]
    print(f"[serve] completed {len(done)}/{len(trace)}; "
          f"TTFT mean {metrics.ttft_mean:.3f}s, "
          f"p99 latency {metrics.p99_response:.3f}s, "
          f"reprefill {metrics.reprefill_tokens} tokens")
    if done:
        print(f"[serve] sample output ({done[0].rid}): "
              f"{done[0].output_tokens[:12]}")
    if not done or not live.done:
        print("[serve] FAILED: no completed requests", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
