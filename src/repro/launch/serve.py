"""Serving launcher: run the full SCLS stack on real JAX engines.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --workers 2 --rate 2 --duration 15 --strategy scls

  # prediction-aware scheduling (repro.predict): online histogram predictor
  PYTHONPATH=src python -m repro.launch.serve --strategy scls-pred \
      --predictor histogram --coverage 0.7

Profiles the engine, fits the Eq. 3/4 estimator, then drives the DP
batcher + max-min offloader over in-process workers (virtual-time clocks;
every token really computed).  On a real TPU cluster each worker becomes a
mesh slice and the engine's jit functions land on devices unchanged.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.cluster.realtime import RealCluster
from repro.cluster.trace import WorkloadSpec, generate_trace
from repro.configs import ARCHS, get_config
from repro.core.memory import AnalyticMemoryEstimator, PagedMemoryEstimator
from repro.core.schedulers import ALL_STRATEGIES, make_strategy
from repro.engine.profiler import fit_estimator
from repro.engine.static_engine import StaticEngine
from repro.models.registry import get_model
from repro.predict import PREDICTORS

# RealCluster drives central-tick strategies (incl. prediction-aware ones)
_SERVABLE = [s for s in ALL_STRATEGIES
             if make_strategy(s).mode in ("central", "pred")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--strategy", default="scls", choices=_SERVABLE)
    ap.add_argument("--predictor", default="histogram", choices=list(PREDICTORS),
                    help="length predictor for --strategy scls-pred")
    ap.add_argument("--coverage", type=float, default=0.7,
                    help="calibration target quantile for predicted caps")
    ap.add_argument("--kv-layout", default="dense", choices=["dense", "paged"],
                    help="worker KV layout (repro.kvcache): paged reserves "
                         "slice envelopes block by block from a page pool")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="cache slots per KV block for --kv-layout paged")
    ap.add_argument("--slice-len", type=int, default=8)
    ap.add_argument("--max-gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if not 0.0 < args.coverage < 1.0:
        ap.error("--coverage must be in (0, 1)")

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.family not in ("dense", "moe", "ssm", "hybrid"):
        raise SystemExit(f"serve launcher drives token-only archs; "
                         f"{args.arch} needs frontend embeddings (use examples/)")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {args.arch} (reduced={args.reduced}), "
          f"{args.workers} workers, strategy={args.strategy}")

    est, prmse, drmse = fit_estimator(model, params, batch_sizes=(1, 2, 4),
                                      input_lens=(16, 32, 64))
    print(f"[serve] estimator fitted: prefill rmse {prmse*1e3:.2f} ms, "
          f"decode rmse {drmse*1e3:.2f} ms")
    if args.kv_layout == "paged":
        mem = PagedMemoryEstimator(delta_bytes=model.kv_bytes_per_token(),
                                   m_available=256e6, zeta=0.9,
                                   page_tokens=args.page_tokens, bucket=8)
        print(f"[serve] paged KV: {mem.total_blocks} blocks of "
              f"{args.page_tokens} tokens per worker")
    else:
        mem = AnalyticMemoryEstimator(delta_bytes=model.kv_bytes_per_token(),
                                      m_available=256e6, zeta=0.9, bucket=8)
    spec = WorkloadSpec("demo", input_mu=3.0, input_sigma=0.7, gen_mu=2.3,
                        gen_sigma=0.7, max_input=64, max_gen=args.max_gen)
    trace = generate_trace(args.rate, args.duration, spec, seed=args.seed,
                           vocab_size=cfg.vocab_size)
    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)
               for _ in range(args.workers)]
    strategy = make_strategy(args.strategy, slice_len=args.slice_len,
                             max_gen=args.max_gen, gamma=0.25,
                             predictor=args.predictor, coverage=args.coverage,
                             kv_layout=args.kv_layout)
    cluster = RealCluster(strategy, engines, est, mem)
    metrics = cluster.run(trace, args.duration)
    print(json.dumps(dataclasses.asdict(metrics), indent=2))
    if cluster.predictor is not None:
        print(f"[serve] predictor={cluster.predictor.name} "
              f"calibration scale={cluster.calibrator.scale:.2f} "
              f"coverage={cluster.calibrator.empirical_coverage():.2f}")
    done = [r for r in trace if r.done]
    print(f"[serve] completed {len(done)}/{len(trace)}; "
          f"sample output ({done[0].rid}): {done[0].output_tokens[:12]}")


if __name__ == "__main__":
    main()
