"""Parse compiled HLO text for collective traffic (roofline collective term).

cost_analysis() gives FLOPs and bytes but not collective bytes, so we sum
the output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute in the post-SPMD module (per-device shapes,
which is what per-chip link traffic needs).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %x = bf16[2,16,1024]{2,1,0} all-gather(...)
#        %y = (f32[128]{0}, f32[128]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?P<shapes>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Tuple[int, int]]:
    """-> {kind: (op_count, total_output_bytes)} (per device)."""
    out: Dict[str, Tuple[int, int]] = {k: (0, 0) for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # -start already counted with the same output shape
        kind = m.group("kind")
        b = _shape_bytes(m.group("shapes"))
        c, t = out[kind]
        out[kind] = (c + 1, t + b)
    return {k: v for k, v in out.items() if v[0] > 0}


def total_collective_bytes(hlo_text: str) -> int:
    return sum(b for _, b in parse_collectives(hlo_text).values())


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
