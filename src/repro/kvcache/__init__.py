"""Paged KV-cache subsystem: block-granular allocation for serving engines.

The paper's slice-level analysis gives each scheduled request an *exact*
memory envelope of ``(L_i + S)·Δ`` bytes (Eq. 5), yet a dense engine still
reserves a contiguous worst-case ``(B, W)`` region per slot — throwing the
tight bound away at the allocator and capping parallelism exactly the way
the paper criticizes ILS for.  This package makes slice-granular
reservations *real* allocations:

  * ``PageAllocator`` — fixed-size token blocks, a free list, per-owner
    block lists, ``reserve(owner, n_tokens)`` / ``release(owner)`` keyed to
    the scheduler's ``(L_i + S)`` bound;
  * ``PagedKVCache`` — the device-side page pool + per-row block tables
    consumed by ``models.transformer.decode_step_paged`` and the Pallas
    kernel ``kernels.paged_decode_attention``.

``core.memory.PagedMemoryEstimator`` exposes the same pool to the DP
batcher (Algorithm 1), counting free blocks instead of the ζ·M_ava closed
form.
"""
from repro.core.memory import blocks_for
from repro.kvcache.allocator import PageAllocator
from repro.kvcache.paged import (PagedKVCache, append_prefill,
                                 batch_block_table, batch_slot_pos,
                                 clear_row, init_paged_kv_cache,
                                 write_prefill_pages)
from repro.kvcache.prefix import PrefixIndex

__all__ = [
    "PageAllocator",
    "PagedKVCache",
    "PrefixIndex",
    "append_prefill",
    "batch_block_table",
    "batch_slot_pos",
    "blocks_for",
    "init_paged_kv_cache",
    "clear_row",
    "write_prefill_pages",
]
