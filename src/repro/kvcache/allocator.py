"""Page-table KV allocator: fixed-size token blocks with a free list.

Host-side bookkeeping only — the device arrays live in
``kvcache.paged.PagedKVCache``.  Page 0 is reserved as the *null page*:
block-table rows of inactive slots and the padding entries of short rows
all point at it, so masked writes land somewhere harmless and gathers
through a padded table never index out of bounds.  The null page is never
handed out and its slots are permanently masked (``slot_pos = -1``).

``reserve(owner, n_tokens)`` is keyed to the scheduler's ``(L_i + S)``
bound (paper Eq. 5): the engine reserves exactly the slice envelope at
join/slice-start and releases it at eviction/slice-end, so the tight
per-slice memory analysis survives all the way down to the allocator.

Pages are *refcounted*: ``share(owner, pages)`` maps a new owner onto
pages another owner already holds (cross-request prefix sharing), and a
page only returns to the free list when its last reference drops.  The
copy-on-write obligation is that a page with refcount > 1 is never
mutated — writers call ``fork(owner, index)`` first, which swaps in a
private copy when (and only when) the page is shared.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

# single source of the block-rounding rule, shared with the estimator
from repro.core.memory import blocks_for


class PageAllocator:
    """Fixed-size token-block allocator with per-owner block lists.

    ``n_pages`` counts usable pages (the null page is allocated on top of
    it), so capacity comparisons against a dense layout stay apples to
    apples: ``n_pages * page_tokens`` usable cache slots.
    """

    NULL_PAGE = 0

    def __init__(self, n_pages: int, page_tokens: int):
        if n_pages <= 0:
            raise ValueError(f"need at least one usable page, got {n_pages}")
        if page_tokens <= 0:
            raise ValueError(f"page_tokens must be positive, got {page_tokens}")
        self.page_tokens = page_tokens
        self.n_pages = n_pages
        # page ids 1..n_pages are usable; 0 is the null page
        self._free: List[int] = list(range(n_pages, 0, -1))  # pop() -> low ids
        self._owned: Dict[int, List[int]] = {}
        # live reference count per page; absent == page is on the free list
        self._refs: Dict[int, int] = {}

    # ------------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def shared_blocks(self) -> int:
        """Number of distinct pages currently held by more than one owner."""
        return sum(1 for r in self._refs.values() if r > 1)

    def ref_count(self, page: int) -> int:
        """Live references on ``page`` (0 == on the free list)."""
        return self._refs.get(page, 0)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.page_tokens)

    def can_reserve(self, n_tokens: int) -> bool:
        return self.blocks_for_tokens(n_tokens) <= self.free_blocks

    # ------------------------------------------------------------------
    def reserve(self, owner: int, n_tokens: int) -> List[int]:
        """Reserve pages for ``n_tokens`` cache slots; returns the page ids.

        All-or-nothing: raises ``MemoryError`` when the free list is short
        (callers gate with ``can_reserve`` — a waiting request simply stays
        queued, which is the whole point: parallelism is bounded by *real*
        free memory, not a conservative slot count).
        """
        if owner in self._owned:
            raise KeyError(f"owner {owner} already holds pages")
        need = self.blocks_for_tokens(n_tokens)
        if need > self.free_blocks:
            raise MemoryError(
                f"owner {owner}: need {need} blocks, {self.free_blocks} free")
        pages = [self._free.pop() for _ in range(need)]
        for p in pages:
            self._refs[p] = 1
        self._owned[owner] = pages
        return list(pages)

    def extend(self, owner: int, n_tokens: int) -> List[int]:
        """Grow ``owner``'s reservation to cover ``n_tokens`` cache slots;
        returns the newly added page ids (``[]`` when it already covers).

        The retention path's slice-start call (kv_retain="request"): a
        resumed request holds its trimmed prefix pages and only the slice
        growth ``+S`` is new.  All-or-nothing like ``reserve`` — on
        ``MemoryError`` the owner's existing pages are untouched.
        """
        pages = self._owned.get(owner)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages — use reserve()")
        need = self.blocks_for_tokens(n_tokens) - len(pages)
        if need <= 0:
            return []
        if need > self.free_blocks:
            raise MemoryError(
                f"owner {owner}: extend needs {need} blocks, "
                f"{self.free_blocks} free")
        new = [self._free.pop() for _ in range(need)]
        for p in new:
            self._refs[p] = 1
        pages.extend(new)
        return list(new)

    def shrink(self, owner: int, n_tokens: int) -> int:
        """Return ``owner``'s trailing pages beyond ``n_tokens`` coverage to
        the free list; returns the count freed.

        The retention path's slice-end trim: the slice envelope reserved
        ``(resident + S)`` but only ``steps <= S`` tokens were written, so
        the slack pages go back to the pool while the prefix stays
        resident.  Pages are freed from the tail (highest logical blocks),
        so the retained prefix mapping is untouched.
        """
        pages = self._owned.get(owner)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages")
        keep = self.blocks_for_tokens(n_tokens)
        freed = 0
        while len(pages) > max(keep, 0):
            self._drop_ref(pages.pop())
            freed += 1
        return freed

    def release(self, owner: int, *, missing_ok: bool = False) -> int:
        """Return ``owner``'s pages to the free list; returns the count.

        Releasing an owner that holds nothing is a bug by default — the
        classic shape is cancel-then-slice-end calling ``release`` twice,
        which with a laxer allocator would silently double-free pages onto
        the free list and hand the same page to two owners.  It raises a
        descriptive ``KeyError``; pass ``missing_ok=True`` at call sites
        where release is legitimately idempotent (then it is an explicit
        no-op returning 0).
        """
        pages = self._owned.pop(owner, None)
        if pages is None:
            if missing_ok:
                return 0
            raise KeyError(
                f"owner {owner} holds no pages — double release? "
                f"(live owners: {sorted(self._owned)})")
        for p in pages:
            self._drop_ref(p)
        return len(pages)

    # ------------------------------------------------------------------
    def share(self, owner: int, pages: Sequence[int]) -> List[int]:
        """Map a *new* owner onto ``pages`` already held by someone else.

        The cross-request prefix join: a request whose token prefix matches
        a resident's full pages takes a reference on those pages instead of
        re-prefilling them.  No allocation happens — the shared pages become
        the head of ``owner``'s block list (callers ``extend`` afterwards
        for the novel tail).  Every page must be live (refcount >= 1);
        sharing a free-list page would alias freshly handed-out memory.
        """
        if owner in self._owned:
            raise KeyError(f"owner {owner} already holds pages")
        for p in pages:
            if self._refs.get(p, 0) < 1:
                raise ValueError(f"page {p} is not live — cannot share")
            if p == self.NULL_PAGE:
                raise ValueError("cannot share the null page")
        for p in pages:
            self._refs[p] += 1
        self._owned[owner] = list(pages)
        return list(pages)

    def fork(self, owner: int, index: int) -> Tuple[int, int]:
        """Copy-on-write: make ``owner``'s ``index``-th page privately
        writable; returns ``(old_page, new_page)``.

        When the page is exclusively held (refcount == 1) this is a no-op
        and ``old == new``.  When it is shared, a fresh page is allocated
        (``MemoryError`` if the pool is dry), the shared page loses one
        reference, and the owner's block table entry is swapped — the
        caller must then copy the device-side page contents ``old -> new``
        before writing.  The shared page itself is never mutated.
        """
        pages = self._owned.get(owner)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages")
        page = pages[index]
        if self._refs[page] == 1:
            return page, page
        if not self._free:
            raise MemoryError(f"owner {owner}: fork needs 1 block, 0 free")
        new = self._free.pop()
        self._refs[new] = 1
        self._refs[page] -= 1
        pages[index] = new
        return page, new

    def _drop_ref(self, page: int) -> bool:
        """Drop one reference; free the page when the last one goes."""
        r = self._refs[page] - 1
        if r == 0:
            del self._refs[page]
            self._free.append(page)
            return True
        self._refs[page] = r
        return False

    def pages_of(self, owner: int) -> List[int]:
        return list(self._owned[owner])

    def owners(self) -> List[int]:
        return list(self._owned)
