"""Page-table KV allocator: fixed-size token blocks with a free list.

Host-side bookkeeping only — the device arrays live in
``kvcache.paged.PagedKVCache``.  Page 0 is reserved as the *null page*:
block-table rows of inactive slots and the padding entries of short rows
all point at it, so masked writes land somewhere harmless and gathers
through a padded table never index out of bounds.  The null page is never
handed out and its slots are permanently masked (``slot_pos = -1``).

``reserve(owner, n_tokens)`` is keyed to the scheduler's ``(L_i + S)``
bound (paper Eq. 5): the engine reserves exactly the slice envelope at
join/slice-start and releases it at eviction/slice-end, so the tight
per-slice memory analysis survives all the way down to the allocator.
"""
from __future__ import annotations

from typing import Dict, List

# single source of the block-rounding rule, shared with the estimator
from repro.core.memory import blocks_for


class PageAllocator:
    """Fixed-size token-block allocator with per-owner block lists.

    ``n_pages`` counts usable pages (the null page is allocated on top of
    it), so capacity comparisons against a dense layout stay apples to
    apples: ``n_pages * page_tokens`` usable cache slots.
    """

    NULL_PAGE = 0

    def __init__(self, n_pages: int, page_tokens: int):
        if n_pages <= 0:
            raise ValueError(f"need at least one usable page, got {n_pages}")
        if page_tokens <= 0:
            raise ValueError(f"page_tokens must be positive, got {page_tokens}")
        self.page_tokens = page_tokens
        self.n_pages = n_pages
        # page ids 1..n_pages are usable; 0 is the null page
        self._free: List[int] = list(range(n_pages, 0, -1))  # pop() -> low ids
        self._owned: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_pages - len(self._free)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.page_tokens)

    def can_reserve(self, n_tokens: int) -> bool:
        return self.blocks_for_tokens(n_tokens) <= self.free_blocks

    # ------------------------------------------------------------------
    def reserve(self, owner: int, n_tokens: int) -> List[int]:
        """Reserve pages for ``n_tokens`` cache slots; returns the page ids.

        All-or-nothing: raises ``MemoryError`` when the free list is short
        (callers gate with ``can_reserve`` — a waiting request simply stays
        queued, which is the whole point: parallelism is bounded by *real*
        free memory, not a conservative slot count).
        """
        if owner in self._owned:
            raise KeyError(f"owner {owner} already holds pages")
        need = self.blocks_for_tokens(n_tokens)
        if need > self.free_blocks:
            raise MemoryError(
                f"owner {owner}: need {need} blocks, {self.free_blocks} free")
        pages = [self._free.pop() for _ in range(need)]
        self._owned[owner] = pages
        return list(pages)

    def extend(self, owner: int, n_tokens: int) -> List[int]:
        """Grow ``owner``'s reservation to cover ``n_tokens`` cache slots;
        returns the newly added page ids (``[]`` when it already covers).

        The retention path's slice-start call (kv_retain="request"): a
        resumed request holds its trimmed prefix pages and only the slice
        growth ``+S`` is new.  All-or-nothing like ``reserve`` — on
        ``MemoryError`` the owner's existing pages are untouched.
        """
        pages = self._owned.get(owner)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages — use reserve()")
        need = self.blocks_for_tokens(n_tokens) - len(pages)
        if need <= 0:
            return []
        if need > self.free_blocks:
            raise MemoryError(
                f"owner {owner}: extend needs {need} blocks, "
                f"{self.free_blocks} free")
        new = [self._free.pop() for _ in range(need)]
        pages.extend(new)
        return list(new)

    def shrink(self, owner: int, n_tokens: int) -> int:
        """Return ``owner``'s trailing pages beyond ``n_tokens`` coverage to
        the free list; returns the count freed.

        The retention path's slice-end trim: the slice envelope reserved
        ``(resident + S)`` but only ``steps <= S`` tokens were written, so
        the slack pages go back to the pool while the prefix stays
        resident.  Pages are freed from the tail (highest logical blocks),
        so the retained prefix mapping is untouched.
        """
        pages = self._owned.get(owner)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages")
        keep = self.blocks_for_tokens(n_tokens)
        freed = 0
        while len(pages) > max(keep, 0):
            self._free.append(pages.pop())
            freed += 1
        return freed

    def release(self, owner: int, *, missing_ok: bool = False) -> int:
        """Return ``owner``'s pages to the free list; returns the count.

        Releasing an owner that holds nothing is a bug by default — the
        classic shape is cancel-then-slice-end calling ``release`` twice,
        which with a laxer allocator would silently double-free pages onto
        the free list and hand the same page to two owners.  It raises a
        descriptive ``KeyError``; pass ``missing_ok=True`` at call sites
        where release is legitimately idempotent (then it is an explicit
        no-op returning 0).
        """
        pages = self._owned.pop(owner, None)
        if pages is None:
            if missing_ok:
                return 0
            raise KeyError(
                f"owner {owner} holds no pages — double release? "
                f"(live owners: {sorted(self._owned)})")
        self._free.extend(pages)
        return len(pages)

    def pages_of(self, owner: int) -> List[int]:
        return list(self._owned[owner])

    def owners(self) -> List[int]:
        return list(self._owned)
