"""Device-side paged KV cache: a shared page pool + per-row block tables.

Layout (cf. vLLM's PagedAttention, adapted to the repo's slot_pos
convention):

  * ``k_pages``/``v_pages`` — (L, P, pg, Hkv, D): P physical pages of
    ``pg`` token slots each, shared by all batch rows (page 0 is the null
    page, see ``kvcache.allocator``);
  * ``block_table`` — (B, nb) int32: logical block j of row b lives in
    physical page ``block_table[b, j]`` (0 = unused → null page);
  * ``slot_pos`` — (B, nb·pg) int32: absolute position stored in each
    *logical* slot, -1 = empty — the exact masking convention of the dense
    ``models.attention.KVCache``, so full, ring, and paged caches all look
    identical to the attention math and the Pallas kernels.

A row's logical cache is the gather ``k_pages[block_table[b]]`` reshaped
to (nb·pg, Hkv, D); the Pallas kernel streams that gather page by page
through scalar-prefetched block tables instead of materializing it.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np


class PagedKVCache(NamedTuple):
    """Per-model paged KV cache; k/v carry a leading layer axis."""

    k_pages: jnp.ndarray     # (L, P, pg, Hkv, D)
    v_pages: jnp.ndarray     # (L, P, pg, Hkv, D)
    block_table: jnp.ndarray  # (B, nb) int32 physical page per logical block
    slot_pos: jnp.ndarray    # (B, nb·pg) int32 absolute position, -1 empty
    lengths: jnp.ndarray     # (B,) int32 real (unpadded) input lengths

    @property
    def page_tokens(self) -> int:
        return self.k_pages.shape[2]

    @property
    def window(self) -> int:
        """Logical cache width per row (matches dense ``KVCache.window``)."""
        return self.block_table.shape[1] * self.k_pages.shape[2]

    @property
    def n_pages(self) -> int:
        """Physical pages including the null page."""
        return self.k_pages.shape[1]


def init_paged_kv_cache(n_layers: int, batch: int, n_pages: int,
                        page_tokens: int, max_blocks_per_row: int,
                        n_kv: int, head_dim: int, dtype: Any) -> PagedKVCache:
    """``n_pages`` usable pages; one extra null page (id 0) is added."""
    P = n_pages + 1
    return PagedKVCache(
        k_pages=jnp.zeros((n_layers, P, page_tokens, n_kv, head_dim), dtype),
        v_pages=jnp.zeros((n_layers, P, page_tokens, n_kv, head_dim), dtype),
        block_table=jnp.zeros((batch, max_blocks_per_row), jnp.int32),
        slot_pos=jnp.full((batch, max_blocks_per_row * page_tokens), -1,
                          jnp.int32),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def write_prefill_pages(cache: PagedKVCache, row: int, page_ids: List[int],
                        k: jnp.ndarray, v: jnp.ndarray,
                        prefill_slot_pos: jnp.ndarray, length: int
                        ) -> PagedKVCache:
    """Scatter one request's prefill K/V (L, T, Hkv, D) into its pages.

    ``page_ids`` are the allocator's pages for this row (first block first);
    T must fit in them.  ``prefill_slot_pos`` (T,) carries the absolute
    position per prefill slot (pads -1), exactly as the dense prefill
    produces it.
    """
    L, _, pg, Hkv, D = cache.k_pages.shape
    T = k.shape[1]
    n_used = len(page_ids)
    pad = n_used * pg - T
    if pad < 0:
        raise ValueError(f"{T} prefill slots exceed {n_used} pages of {pg}")
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ids = jnp.asarray(page_ids, jnp.int32)
    nb = cache.block_table.shape[1]
    bt_row = np.zeros((nb,), np.int32)
    bt_row[:n_used] = page_ids
    sp_row = np.full((nb * pg,), -1, np.int32)
    sp_row[:T] = np.asarray(prefill_slot_pos, np.int32)
    return cache._replace(
        k_pages=cache.k_pages.at[:, ids].set(
            kp.reshape(L, n_used, pg, Hkv, D)),
        v_pages=cache.v_pages.at[:, ids].set(
            vp.reshape(L, n_used, pg, Hkv, D)),
        block_table=cache.block_table.at[row].set(jnp.asarray(bt_row)),
        slot_pos=cache.slot_pos.at[row].set(jnp.asarray(sp_row)),
        lengths=cache.lengths.at[row].set(length),
    )


def append_prefill(cache: PagedKVCache, row: int, page_ids: List[int],
                   k: jnp.ndarray, v: jnp.ndarray, start: int, n_new: int
                   ) -> PagedKVCache:
    """Append K/V for tokens ``[start, start + n_new)`` of one row.

    The *compact* layout (logical slot == absolute position, no pad
    slots) used by the persistent-paged engine: ``page_ids`` is the row's
    full page list (first block first), ``k``/``v`` are (L, n_new, Hkv, D)
    for just the new tokens.  Slots before ``start`` (the retained
    prefix) are untouched; slot_pos/lengths/block_table are refreshed for
    the row.  This is the host-side twin of the batched in-graph path
    (``models.transformer.prefill_paged``) — used for single-row delta
    prefills and as the reference in tests.
    """
    L, _, pg, Hkv, D = cache.k_pages.shape
    n_total = start + n_new
    if n_total > len(page_ids) * pg:
        raise ValueError(f"{n_total} slots exceed {len(page_ids)} pages "
                         f"of {pg}")
    nb = cache.block_table.shape[1]
    if len(page_ids) > nb:
        raise ValueError(f"{len(page_ids)} pages exceed the {nb}-block table")
    k_pages, v_pages = cache.k_pages, cache.v_pages
    for t in range(n_new):
        slot = start + t
        page, off = page_ids[slot // pg], slot % pg
        k_pages = k_pages.at[:, page, off].set(k[:, t])
        v_pages = v_pages.at[:, page, off].set(v[:, t])
    bt_row = np.zeros((nb,), np.int32)
    bt_row[:len(page_ids)] = page_ids
    sp_row = np.full((nb * pg,), -1, np.int32)
    sp_row[:n_total] = np.arange(n_total)
    return cache._replace(
        k_pages=k_pages, v_pages=v_pages,
        block_table=cache.block_table.at[row].set(jnp.asarray(bt_row)),
        slot_pos=cache.slot_pos.at[row].set(jnp.asarray(sp_row)),
        lengths=cache.lengths.at[row].set(n_total),
    )


def batch_block_table(pages_per_row: List[List[int]], n_blocks: int
                      ) -> np.ndarray:
    """Assemble a (B, nb) block table from per-row page lists (padded with
    the null page) — how the persistent engine remaps each batch member's
    retained pages into the dispatched batch's table."""
    B = len(pages_per_row)
    bt = np.zeros((B, n_blocks), np.int32)
    for b, pages in enumerate(pages_per_row):
        if len(pages) > n_blocks:
            raise ValueError(f"row {b}: {len(pages)} pages exceed the "
                             f"{n_blocks}-block table")
        bt[b, :len(pages)] = pages
    return bt


def batch_slot_pos(lengths: List[int], n_blocks: int, page_tokens: int
                   ) -> np.ndarray:
    """(B, nb·pg) slot_pos for the compact layout: slot s of row b holds
    absolute position s for s < lengths[b], -1 (masked) beyond."""
    W = n_blocks * page_tokens
    slots = np.arange(W, dtype=np.int32)[None]
    lens = np.asarray(lengths, np.int32)[:, None]
    return np.where(slots < lens, slots, -1).astype(np.int32)


def clear_row(cache: PagedKVCache, row: int) -> PagedKVCache:
    """Evict a row: point its blocks at the null page and mask every slot.

    The page contents are left dirty — once unmapped and masked they are
    unreachable, and the allocator will hand the pages to a new owner whose
    prefill overwrites them.
    """
    return cache._replace(
        block_table=cache.block_table.at[row].set(0),
        slot_pos=cache.slot_pos.at[row].set(-1),
    )


def gather_row(cache: PagedKVCache, row: int) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize row's logical (L, nb·pg, Hkv, D) K/V — debug/test helper."""
    L, _, pg, Hkv, D = cache.k_pages.shape
    bt = np.asarray(cache.block_table[row])
    k = np.asarray(cache.k_pages[:, bt]).reshape(L, -1, Hkv, D)
    v = np.asarray(cache.v_pages[:, bt]).reshape(L, -1, Hkv, D)
    return k, v
