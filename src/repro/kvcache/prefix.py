"""Page-granular prefix index: longest-common-prefix lookup over resident KV.

The cross-request sharing map (ISSUE 7 / ROADMAP "multi-turn + prefix
sharing"): a trie keyed by *full-page token content* — each edge is the
``page_tokens``-tuple of token ids filling one KV page — whose nodes record,
per resident owner, the physical page holding exactly that content.  A new
request walks the trie with its own prompt and receives the longest chain of
already-resident pages whose content matches its prefix; the engine then
``share()``s those pages and prefills only the novel tail.

Only *full* pages are indexed.  An owner writes KV solely at its frontier
(the next empty slot), so a full page behind the frontier is immutable for
the rest of the owner's lifetime — sharing it can never observe a write,
which is what makes page-granular sharing safe without fork-on-write on the
decode hot path (``PageAllocator.fork`` covers the general COW contract).

Content equality is the correctness argument: KV at a slot depends only on
the token ids at and before it (plus position), so two rows with identical
token prefixes have bitwise-identical KV for those slots and may point their
block tables at the same physical pages.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("children", "owners")

    def __init__(self) -> None:
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.owners: Dict[int, int] = {}  # owner -> physical page id


class PrefixIndex:
    """Trie of full-page token content over resident owners' pages."""

    def __init__(self, page_tokens: int):
        if page_tokens <= 0:
            raise ValueError(f"page_tokens must be positive, got {page_tokens}")
        self.page_tokens = page_tokens
        self._root = _Node()
        # owner -> the node path it is registered on (depth order)
        self._paths: Dict[int, List[_Node]] = {}

    # ------------------------------------------------------------------
    def insert(self, owner: int, tokens: Sequence[int],
               pages: Sequence[int]) -> int:
        """(Re-)index ``owner``'s resident stream; returns #pages indexed.

        ``tokens`` is the owner's full resident token stream and ``pages``
        its physical block list; only the leading full pages (both token-
        and page-covered) enter the trie.  Re-inserting an owner replaces
        its previous entry.
        """
        if owner in self._paths:
            self.remove(owner)
        pg = self.page_tokens
        n_full = min(len(tokens) // pg, len(pages))
        node, path = self._root, []
        for i in range(n_full):
            key = tuple(int(t) for t in tokens[i * pg:(i + 1) * pg])
            node = node.children.setdefault(key, _Node())
            node.owners[owner] = int(pages[i])
            path.append(node)
        if path:
            self._paths[owner] = path
        return len(path)

    def remove(self, owner: int) -> None:
        """Drop ``owner``'s entry (no-op when absent); prunes empty nodes."""
        path = self._paths.pop(owner, None)
        if path is None:
            return
        for node in path:
            node.owners.pop(owner, None)
        # prune bottom-up: a node with no owners has no live subtree either
        # (every descendant registration also registers the ancestors)
        parents = [self._root] + path[:-1]
        for node, parent in zip(reversed(path), reversed(parents)):
            if node.owners:
                break
            for key, child in list(parent.children.items()):
                if child is node:
                    del parent.children[key]
                    break

    def lookup(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest resident full-page prefix of ``tokens``.

        Returns ``(pages, hit_tokens)`` — the physical pages covering the
        match (possibly contributed by different owners at different
        depths; content equality makes the mix coherent) and the number of
        tokens they cover.  ``([], 0)`` on a miss.
        """
        pg = self.page_tokens
        node, pages = self._root, []
        for i in range(len(tokens) // pg):
            key = tuple(int(t) for t in tokens[i * pg:(i + 1) * pg])
            child = node.children.get(key)
            if child is None or not child.owners:
                break
            # deterministic donor: the lowest live owner id at this depth
            pages.append(child.owners[min(child.owners)])
            node = child
        return pages, len(pages) * pg

    # ------------------------------------------------------------------
    def owners(self) -> List[int]:
        return list(self._paths)

    def __contains__(self, owner: int) -> bool:
        return owner in self._paths
