"""Length-prediction sweep: SCLS vs SCLS-PRED vs ORACLE (repro.predict).

  PYTHONPATH=src python -m benchmarks.bench_predictor [--full]

Runs the cluster simulator in a memory-constrained regime (where KV
capacity binds the batch size, so knowing generation lengths pays the
most — the S³ setting) on both paper workloads, comparing:

  scls            — length-blind slice-level scheduling (the paper);
  scls-pred:hist  — online KM-histogram predictor + quantile calibration;
  scls-pred:proxy — online JAX proxy-MLP predictor (arXiv 2404.08509
                    style; on synthetic traces the prompt carries no
                    length signal, so this shows API + training cost,
                    not predictive headroom);
  oracle          — perfect predictions: the upper bound.

Expected shape: throughput(scls) < throughput(scls-pred:hist) <
throughput(oracle), with invalid-token rates dropping in the same order.
"""
from __future__ import annotations

import copy

from benchmarks.common import DURATION, emit, fitted_estimator
from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import WORKLOADS, generate_trace
from repro.core.estimator import a100_llama13b_profile
from repro.core.memory import AnalyticMemoryEstimator, LLAMA2_13B_DELTA
from repro.core.schedulers import make_strategy

# memory-constrained testbed: ~6 GB KV budget instead of the A100's 50 GB
MEM_AVAILABLE = 6e9
RATE = 24.0
N_WORKERS = 4
COVERAGE = 0.7

VARIANTS = (
    ("scls", "scls", {}),
    ("scls-pred:hist", "scls-pred", {"predictor": "histogram"}),
    ("scls-pred:proxy", "scls-pred", {"predictor": "proxy"}),
    ("oracle", "oracle", {}),
)


def bench_predictor(duration: float = None, rate: float = RATE,
                    n_workers: int = N_WORKERS, seed: int = 1):
    duration = duration or DURATION
    true_lat = a100_llama13b_profile()
    est = fitted_estimator(true_lat)
    rows = []
    for wl_name, spec in WORKLOADS.items():
        trace = generate_trace(rate, duration, spec, seed=seed)
        for label, strat, kw in VARIANTS:
            mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                          m_available=MEM_AVAILABLE, zeta=0.9)
            s = make_strategy(strat, slice_len=128, gamma=3.0,
                              coverage=COVERAGE, **kw)
            sim = ClusterSimulator(s, n_workers, true_lat, est, mem,
                                   noise_sigma=0.02, seed=seed + 1)
            res = sim.run(copy.deepcopy(trace), duration)
            m = res.metrics
            total_tokens = sum(r.generated + r.invalid_tokens
                               for r in res.requests)
            invalid = sum(r.invalid_tokens for r in res.requests)
            rows.append({
                "workload": wl_name,
                "variant": label,
                "throughput": round(m.throughput, 4),
                "invalid_token_rate": round(invalid / max(total_tokens, 1), 4),
                "avg_invalid_tokens": round(m.avg_invalid_tokens, 2),
                "avg_schedules": round(m.avg_schedules, 2),
                "mean_response": round(m.mean_response, 2),
                "p95_response": round(m.p95_response, 2),
                "calib_scale": (round(sim.calibrator.scale, 3)
                                if sim.calibrator else ""),
                "calib_coverage": (round(sim.calibrator.empirical_coverage(), 3)
                                   if sim.calibrator else ""),
            })
            print(f"[bench_predictor] {wl_name:9s} {label:15s} "
                  f"thr={m.throughput:6.3f} req/s  "
                  f"invalid_rate={rows[-1]['invalid_token_rate']:.3f}  "
                  f"resp={m.mean_response:6.1f}s")
    emit(rows, "bench_predictor")
    return rows


if __name__ == "__main__":
    bench_predictor()
