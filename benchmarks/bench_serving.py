"""Async online-serving benchmark: concurrent client load against
``AsyncSliceServer`` (sim backend), with and without SLO-aware admission.

Two load shapes, both running real asyncio clients over the real
scheduler code (this is NOT offline trace replay — every request goes
through ``submit`` → admission → pacer → per-slice wakeups):

  * **closed loop** — N client coroutines, each submitting its next
    request only after the previous one completes (think SDK users in a
    retry loop).  Concurrency is bounded by construction, so admission
    mostly passes; this arm measures the async front end's baseline
    latency accounting.
  * **open loop (Poisson)** — arrivals at a fixed rate regardless of
    completions, the paper's workload model, run under wall-clock pacing
    (``time_scale``) so inter-arrival gaps are real sleeps.  At rates
    beyond capacity the no-admission arm queues unboundedly and SLO
    attainment collapses; the admission arm sheds doomed requests at
    submit (429-equivalent) and keeps *goodput* — completions that met
    their SLO per second — from degrading.

Emits ``bench_results/BENCH_serving.json`` (meta + one row per arm) to
seed the serving perf trajectory, and prints the rows as CSV.

  PYTHONPATH=src python -m benchmarks.bench_serving [--full]
"""
from __future__ import annotations

import asyncio
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.trace import CODEFUSE
from repro.serving import (AdmissionRejected, NO_ADMISSION, AdmissionController,
                           AsyncSliceServer, ServingConfig)

FULL = "--full" in sys.argv
OUT_DIR = os.environ.get("BENCH_OUT", "bench_results")

#: virtual seconds served per wall second in the open-loop arms —
#: compresses the paper-scale trace into CI-friendly wall time while
#: keeping arrival gaps real sleeps
TIME_SCALE = 200.0
SLO_MS = 60_000.0  # 60 virtual seconds end-to-end, generous at low load


def _build(admission_on: bool, time_scale: Optional[float],
           seed: int) -> AsyncSliceServer:
    cfg = ServingConfig(strategy="scls", workers=4, slice_len=128,
                        gamma=3.0, noise_sigma=0.02, seed=seed,
                        time_scale=time_scale)
    server = cfg.build_sim().aio
    server.admission = (AdmissionController() if admission_on
                        else NO_ADMISSION)
    server.default_slo_ms = SLO_MS  # deadlines recorded on both arms
    return server


def _sample_lens(rng: np.random.Generator, n: int):
    spec = CODEFUSE
    ins = np.clip(np.round(rng.lognormal(spec.input_mu, spec.input_sigma, n)),
                  1, spec.max_input).astype(int)
    gens = np.clip(np.round(rng.lognormal(spec.gen_mu, spec.gen_sigma, n)),
                   1, spec.max_gen).astype(int)
    return ins, gens


def _row(name: str, admission_on: bool, server: AsyncSliceServer,
         handles: List, duration: float, extra: Dict) -> Dict:
    m = server.metrics(duration)
    done = [h for h in handles if h.done]
    good = [h for h in done if h.request.deadline is None
            or h.request.finish_time <= h.request.deadline]
    span = max(m.makespan, duration, 1e-9)
    return dict(scenario=name, admission="on" if admission_on else "off",
                n_submitted=server.n_submitted,
                n_rejected=server.n_rejected,
                n_completed=m.n_completed,
                slo_attainment=round(m.slo_attainment, 4),
                goodput_rps=round(len(good) / span, 3),
                throughput_rps=round(m.throughput, 3),
                ttft_mean_s=round(m.ttft_mean, 3),
                p99_response_s=round(m.p99_response, 3),
                **extra)


# ---------------------------------------------------------------------------
async def closed_loop(admission_on: bool, n_clients: int,
                      per_client: int, seed: int = 0) -> Dict:
    server = _build(admission_on, time_scale=None, seed=seed)
    rng = np.random.default_rng(seed)
    ins, gens = _sample_lens(rng, n_clients * per_client)
    handles: List = []

    async def client(i: int) -> None:
        for j in range(per_client):
            k = i * per_client + j
            try:
                h = server.submit(input_len=int(ins[k]), gen_len=int(gens[k]))
            except AdmissionRejected:
                continue
            handles.append(h)
            await h.result()

    await asyncio.gather(*(client(i) for i in range(n_clients)))
    row = _row("closed_loop", admission_on, server, handles, server.now,
               dict(n_clients=n_clients, per_client=per_client))
    await server.close()
    return row


async def open_loop(admission_on: bool, rate: float, duration: float,
                    seed: int = 0) -> Dict:
    """Poisson arrivals at ``rate`` req/s of *virtual* time, paced at
    TIME_SCALE virtual seconds per wall second."""
    server = _build(admission_on, time_scale=TIME_SCALE, seed=seed)
    rng = np.random.default_rng(seed + 1)
    n = int(rng.poisson(rate * duration))
    gaps = rng.exponential(1.0 / rate, size=n)
    ins, gens = _sample_lens(rng, n)
    handles: List = []
    waiters: List[asyncio.Task] = []

    async def arrivals() -> None:
        for k in range(n):
            await asyncio.sleep(gaps[k] / TIME_SCALE)
            try:
                h = server.submit(input_len=int(ins[k]), gen_len=int(gens[k]))
            except AdmissionRejected:
                continue
            handles.append(h)
            waiters.append(asyncio.ensure_future(h.result()))

    await arrivals()
    if waiters:
        await asyncio.gather(*waiters)
    row = _row("open_loop_poisson", admission_on, server, handles, duration,
               dict(rate=rate, duration=duration))
    await server.close()
    return row


# ---------------------------------------------------------------------------
def bench_serving() -> List[Dict]:
    rows: List[Dict] = []
    n_clients, per_client = (16, 8) if FULL else (8, 3)
    duration = 120.0 if FULL else 45.0
    rates = (16.0, 28.0) if FULL else (24.0,)
    for admission_on in (False, True):
        rows.append(asyncio.run(closed_loop(admission_on, n_clients,
                                            per_client)))
        for rate in rates:  # beyond the ~20 req/s 4-worker capacity knee
            rows.append(asyncio.run(open_loop(admission_on, rate, duration)))

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(dict(meta=dict(strategy="scls", workers=4, slice_len=128,
                                 slo_ms=SLO_MS, time_scale=TIME_SCALE,
                                 full=FULL),
                       rows=rows), f, indent=2)
    print(f"[bench_serving] -> {path}")

    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))

    # the headline claim: under open-loop overload, admission keeps SLO
    # attainment of *admitted* work high instead of letting every request
    # blow its deadline in the queue
    on = [r for r in rows if r["scenario"] == "open_loop_poisson"
          and r["admission"] == "on"]
    off = [r for r in rows if r["scenario"] == "open_loop_poisson"
           and r["admission"] == "off"]
    assert on and off
    assert all(r["n_rejected"] > 0 for r in on), \
        "admission never shed anything at an overload rate"
    assert min(r["slo_attainment"] for r in on) >= \
        max(r["slo_attainment"] for r in off), \
        "admission-on SLO attainment should dominate admission-off"
    return rows


if __name__ == "__main__":
    bench_serving()
