"""Async online-serving benchmark: concurrent client load against
``AsyncSliceServer`` (sim backend), with and without SLO-aware admission.

Two load shapes, both running real asyncio clients over the real
scheduler code (this is NOT offline trace replay — every request goes
through ``submit`` → admission → pacer → per-slice wakeups):

  * **closed loop** — N client coroutines, each submitting its next
    request only after the previous one completes (think SDK users in a
    retry loop).  Concurrency is bounded by construction, so admission
    mostly passes; this arm measures the async front end's baseline
    latency accounting.
  * **open loop (Poisson)** — arrivals at a fixed rate regardless of
    completions, the paper's workload model, run under wall-clock pacing
    (``time_scale``) so inter-arrival gaps are real sleeps.  At rates
    beyond capacity the no-admission arm queues unboundedly and SLO
    attainment collapses; the admission arm sheds doomed requests at
    submit (429-equivalent) and keeps *goodput* — completions that met
    their SLO per second — from degrading.

Emits ``bench_results/BENCH_serving.json`` (meta + one row per arm) to
seed the serving perf trajectory, and prints the rows as CSV.

A third arm runs open-loop load with ``repro.obs`` tracing on and derives
a **per-phase breakdown** — prefill vs decode vs scheduling gap (worker
idle time between slice spans) — from the Chrome trace's slice sub-spans,
emitting ``bench_results/BENCH_obs.json``; ``--trace-out PATH``
additionally writes the raw trace for Perfetto.

  PYTHONPATH=src python -m benchmarks.bench_serving [--full] \
      [--trace-out trace.json]
"""
from __future__ import annotations

import asyncio
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.trace import CODEFUSE
from repro.serving import (AdmissionRejected, NO_ADMISSION, AdmissionController,
                           AsyncSliceServer, ServingConfig)

FULL = "--full" in sys.argv
OUT_DIR = os.environ.get("BENCH_OUT", "bench_results")

#: virtual seconds served per wall second in the open-loop arms —
#: compresses the paper-scale trace into CI-friendly wall time while
#: keeping arrival gaps real sleeps
TIME_SCALE = 200.0
SLO_MS = 60_000.0  # 60 virtual seconds end-to-end, generous at low load


def _build(admission_on: bool, time_scale: Optional[float],
           seed: int, trace: bool = False) -> AsyncSliceServer:
    cfg = ServingConfig(strategy="scls", workers=4, slice_len=128,
                        gamma=3.0, noise_sigma=0.02, seed=seed,
                        time_scale=time_scale,
                        # any non-None value turns the tracer on; the
                        # export path is chosen by the caller
                        trace_out="trace.json" if trace else None)
    server = cfg.build_sim().aio
    server.admission = (AdmissionController() if admission_on
                        else NO_ADMISSION)
    server.default_slo_ms = SLO_MS  # deadlines recorded on both arms
    return server


def _sample_lens(rng: np.random.Generator, n: int):
    spec = CODEFUSE
    ins = np.clip(np.round(rng.lognormal(spec.input_mu, spec.input_sigma, n)),
                  1, spec.max_input).astype(int)
    gens = np.clip(np.round(rng.lognormal(spec.gen_mu, spec.gen_sigma, n)),
                   1, spec.max_gen).astype(int)
    return ins, gens


def _row(name: str, admission_on: bool, server: AsyncSliceServer,
         handles: List, duration: float, extra: Dict) -> Dict:
    m = server.metrics(duration)
    done = [h for h in handles if h.done]
    good = [h for h in done if h.request.deadline is None
            or h.request.finish_time <= h.request.deadline]
    span = max(m.makespan, duration, 1e-9)
    return dict(scenario=name, admission="on" if admission_on else "off",
                n_submitted=server.n_submitted,
                n_rejected=server.n_rejected,
                n_completed=m.n_completed,
                slo_attainment=round(m.slo_attainment, 4),
                goodput_rps=round(len(good) / span, 3),
                throughput_rps=round(m.throughput, 3),
                ttft_mean_s=round(m.ttft_mean, 3),
                p99_response_s=round(m.p99_response, 3),
                **extra)


# ---------------------------------------------------------------------------
async def closed_loop(admission_on: bool, n_clients: int,
                      per_client: int, seed: int = 0) -> Dict:
    server = _build(admission_on, time_scale=None, seed=seed)
    rng = np.random.default_rng(seed)
    ins, gens = _sample_lens(rng, n_clients * per_client)
    handles: List = []

    async def client(i: int) -> None:
        for j in range(per_client):
            k = i * per_client + j
            try:
                h = server.submit(input_len=int(ins[k]), gen_len=int(gens[k]))
            except AdmissionRejected:
                continue
            handles.append(h)
            await h.result()

    await asyncio.gather(*(client(i) for i in range(n_clients)))
    row = _row("closed_loop", admission_on, server, handles, server.now,
               dict(n_clients=n_clients, per_client=per_client))
    await server.close()
    return row


async def _drive_open_loop(server: AsyncSliceServer, rate: float,
                           duration: float, seed: int) -> List:
    """Poisson arrivals at ``rate`` req/s of *virtual* time, paced at
    TIME_SCALE virtual seconds per wall second; returns admitted handles
    after every one finished."""
    rng = np.random.default_rng(seed + 1)
    n = int(rng.poisson(rate * duration))
    gaps = rng.exponential(1.0 / rate, size=n)
    ins, gens = _sample_lens(rng, n)
    handles: List = []
    waiters: List[asyncio.Task] = []
    for k in range(n):
        await asyncio.sleep(gaps[k] / TIME_SCALE)
        try:
            h = server.submit(input_len=int(ins[k]), gen_len=int(gens[k]))
        except AdmissionRejected:
            continue
        handles.append(h)
        waiters.append(asyncio.ensure_future(h.result()))
    if waiters:
        await asyncio.gather(*waiters)
    return handles


async def open_loop(admission_on: bool, rate: float, duration: float,
                    seed: int = 0) -> Dict:
    server = _build(admission_on, time_scale=TIME_SCALE, seed=seed)
    handles = await _drive_open_loop(server, rate, duration, seed)
    row = _row("open_loop_poisson", admission_on, server, handles, duration,
               dict(rate=rate, duration=duration))
    await server.close()
    return row


# ---------------------------------------------------------------------------
# per-phase breakdown from the Chrome trace (repro.obs)
# ---------------------------------------------------------------------------
def phase_breakdown(tdict: Dict) -> Dict:
    """Prefill vs decode vs scheduling gap, read off the trace spans.

    ``prefill_s``/``decode_s`` sum the per-slice sub-spans the backend
    measured (sim: the latency model's nominal split of the drawn slice
    time).  ``sched_gap_s`` is worker idle time *inside* each worker's
    active window — the span between its first dispatch and last
    completion minus its busy time — i.e. time lost to Γ tick waits and
    queue starvation, the overhead §3.3 prices against slice length.
    All values in core (virtual) seconds.
    """
    spans = [e for e in tdict["traceEvents"] if e.get("ph") == "X"]
    slices = [e for e in spans if e["name"] in ("slice", "cont")]
    prefill_us = sum(e["dur"] for e in spans if e["name"] == "prefill")
    decode_us = sum(e["dur"] for e in spans if e["name"] == "decode")
    busy_us = sum(e["dur"] for e in slices)
    gap_us = 0.0
    by_worker: Dict[int, List[Dict]] = {}
    for e in slices:
        by_worker.setdefault(e["tid"], []).append(e)
    for evs in by_worker.values():
        window = (max(e["ts"] + e["dur"] for e in evs)
                  - min(e["ts"] for e in evs))
        gap_us += max(window - sum(e["dur"] for e in evs), 0.0)
    total = max(busy_us + gap_us, 1e-9)
    return dict(n_slices=len(slices), n_workers=len(by_worker),
                prefill_s=round(prefill_us / 1e6, 6),
                decode_s=round(decode_us / 1e6, 6),
                busy_s=round(busy_us / 1e6, 6),
                sched_gap_s=round(gap_us / 1e6, 6),
                prefill_frac=round(prefill_us / total, 4),
                decode_frac=round(decode_us / total, 4),
                sched_gap_frac=round(gap_us / total, 4))


async def traced_open_loop(rate: float, duration: float, seed: int = 0,
                           trace_out: Optional[str] = None) -> Dict:
    """The obs arm: same open-loop load with the full observability stack
    on (tracer + metrics + audit) — the throughput cost of which is the
    delta against the untraced open-loop rows."""
    server = _build(True, time_scale=TIME_SCALE, seed=seed, trace=True)
    handles = await _drive_open_loop(server, rate, duration, seed)
    row = _row("open_loop_traced", True, server, handles, duration,
               dict(rate=rate, duration=duration))
    obs = server.core.obs
    phases = phase_breakdown(obs.tracer.to_dict())
    ins = obs.ins
    counters = dict(
        slices_dispatched=int(ins.slices.value()),
        reprefill_tokens=int(ins.reprefill.value()),
        trace_events=len(obs.tracer),
        audit_events=obs.audit.n_recorded)
    if trace_out:
        for p in obs.export(trace_out):
            print(f"[bench_serving] wrote {p}")
    await server.close()
    return dict(row=row, phases=phases, counters=counters)


def bench_obs(trace_out: Optional[str] = None) -> Dict:
    rate, duration = (16.0, 120.0) if FULL else (16.0, 45.0)
    out = asyncio.run(traced_open_loop(rate, duration,
                                       trace_out=trace_out))
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(dict(meta=dict(strategy="scls", workers=4, slice_len=128,
                                 rate=rate, duration=duration,
                                 time_scale=TIME_SCALE, full=FULL),
                       **out), f, indent=2)
    print(f"[bench_serving] -> {path}")
    p = out["phases"]
    print(f"[bench_serving] phases: prefill {p['prefill_s']:.2f}s "
          f"({p['prefill_frac']:.0%}) decode {p['decode_s']:.2f}s "
          f"({p['decode_frac']:.0%}) sched gap {p['sched_gap_s']:.2f}s "
          f"({p['sched_gap_frac']:.0%}) over {p['n_slices']} slices")
    assert p["n_slices"] > 0 and p["busy_s"] > 0
    # the sub-spans partition each slice: prefill + decode == busy
    assert abs(p["prefill_s"] + p["decode_s"] - p["busy_s"]) \
        <= 1e-3 * max(p["busy_s"], 1.0)
    return out


# ---------------------------------------------------------------------------
def bench_serving() -> List[Dict]:
    rows: List[Dict] = []
    n_clients, per_client = (16, 8) if FULL else (8, 3)
    duration = 120.0 if FULL else 45.0
    rates = (16.0, 28.0) if FULL else (24.0,)
    for admission_on in (False, True):
        rows.append(asyncio.run(closed_loop(admission_on, n_clients,
                                            per_client)))
        for rate in rates:  # beyond the ~20 req/s 4-worker capacity knee
            rows.append(asyncio.run(open_loop(admission_on, rate, duration)))

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(dict(meta=dict(strategy="scls", workers=4, slice_len=128,
                                 slo_ms=SLO_MS, time_scale=TIME_SCALE,
                                 full=FULL),
                       rows=rows), f, indent=2)
    print(f"[bench_serving] -> {path}")

    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))

    # the headline claim: under open-loop overload, admission keeps SLO
    # attainment of *admitted* work high instead of letting every request
    # blow its deadline in the queue
    on = [r for r in rows if r["scenario"] == "open_loop_poisson"
          and r["admission"] == "on"]
    off = [r for r in rows if r["scenario"] == "open_loop_poisson"
           and r["admission"] == "off"]
    assert on and off
    assert all(r["n_rejected"] > 0 for r in on), \
        "admission never shed anything at an overload rate"
    assert min(r["slo_attainment"] for r in on) >= \
        max(r["slo_attainment"] for r in off), \
        "admission-on SLO attainment should dominate admission-off"
    return rows


def _trace_out_arg() -> Optional[str]:
    if "--trace-out" not in sys.argv:
        return None
    i = sys.argv.index("--trace-out")
    if i + 1 >= len(sys.argv) or sys.argv[i + 1].startswith("--"):
        raise SystemExit("--trace-out requires a path argument")
    return sys.argv[i + 1]


if __name__ == "__main__":
    bench_serving()
    bench_obs(trace_out=_trace_out_arg())
