"""Fleet benchmark: open-loop Poisson traffic through the fleet router
against 3 serving instances, one arm per placement policy.

Each arm stands up three fresh sim-backend instances (full scheduler
stacks behind ``HTTPFrontend``, wall-clock paced) and one
:class:`~repro.fleet.router.FleetRouter`, then drives the *same* seeded
workload through the router:

  * **singles** — open-loop Poisson arrivals (wall-clock sleeps, arrivals
    independent of completions), bimodal sizes: mostly light requests
    plus a heavy tail that punishes count-based placement;
  * **sessions** — multi-turn chats (``session`` ids) whose rendered
    history grows every turn: placement *off* the previous turn's
    instance re-prefills the resident history (§3.3), which the router
    books as ``reprefill_tokens``.

Measured per arm (from the router's own accounting, so identical over
sim and real instances):

  * ``imbalance`` — max/min per-instance served tokens (prompt +
    completion, from proxied usage);
  * ``reprefill_tokens`` — session history recomputed because a turn
    migrated off its pinned instance.

Asserted (the PR 9 acceptance bar): ``retention_affinity`` <=
``round_robin`` on BOTH metrics — the Eq. 10–11 load signal one level
up balances served tokens at least as well as blind rotation while
paying strictly less re-prefill.  Emits
``bench_results/BENCH_fleet.json``.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke]
"""
from __future__ import annotations

import http.client
import json
import os
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.fleet import FleetRouter, imbalance
from repro.serving import HTTPFrontend, ServingConfig

SMOKE = "--smoke" in sys.argv
OUT_DIR = os.environ.get("BENCH_OUT", "bench_results")

#: virtual seconds served per wall second on each instance.  Capacity is
#: host-independent (workers x TIME_SCALE virtual s per wall s vs
#: wall-clock Poisson arrivals), and the value is chosen so the fleet
#: runs ~80% utilized — the load-balancing regime the paper's Eq. 10–11
#: signal exists for; an idle fleet would make every policy look alike
TIME_SCALE = 16.0
N_INSTANCES = 3
ARMS = ("round_robin", "least_load", "retention_affinity")

# workload scale (smoke keeps the same shape, smaller)
RATE = 18.0 if SMOKE else 22.0          # singles per wall second
DURATION = 2.5 if SMOKE else 5.0        # arrival window, wall seconds
N_SESSIONS = 6 if SMOKE else 12
N_TURNS = 3 if SMOKE else 5
POLL_INTERVAL = 0.25


def _build_instances(seed0: int) -> List[HTTPFrontend]:
    fronts = []
    for i in range(N_INSTANCES):
        cfg = ServingConfig(strategy="scls", workers=2, slice_len=32,
                            gamma=0.5, seed=seed0 + i,
                            time_scale=TIME_SCALE)
        fronts.append(HTTPFrontend(cfg.build_sim().aio, port=0).start())
    return fronts


def _post(host: str, port: int, path: str, body: Dict[str, Any],
          timeout: float = 120.0) -> Tuple[int, bytes]:
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("POST", path, json.dumps(body),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def _get_json(host: str, port: int, path: str) -> Dict[str, Any]:
    conn = http.client.HTTPConnection(host, port, timeout=30)
    try:
        conn.request("GET", path)
        return json.loads(conn.getresponse().read())
    finally:
        conn.close()


def _sample_single(rng: np.random.Generator) -> Dict[str, Any]:
    """Bimodal request sizes: the rare heavy tail is what separates
    size-aware placement from count-based rotation — blind rotation
    balances *counts*, so a ~10x token spread between modes keeps its
    token imbalance high even as the request count grows."""
    if rng.random() < 0.1:  # heavy (~13x a light request's tokens)
        prompt = int(rng.integers(24, 48))
        gen = int(rng.integers(384, 640))
    else:                   # light
        prompt = int(rng.integers(4, 16))
        gen = int(rng.integers(16, 40))
    return {"prompt": prompt, "max_tokens": gen}


def _drive_singles(router: FleetRouter, seed: int,
                   errors: List[str]) -> List[threading.Thread]:
    """Open loop: Poisson arrival times are wall sleeps; each arrival
    fires an independent client thread (never waits for completions)."""
    rng = np.random.default_rng(seed)
    bodies = []
    t = 0.0
    while t < DURATION:
        t += float(rng.exponential(1.0 / RATE))
        bodies.append((t, _sample_single(rng)))

    threads: List[threading.Thread] = []

    def client(body: Dict[str, Any]) -> None:
        try:
            status, _ = _post(router.host, router.port,
                              "/v1/completions", body)
            if status != 200:
                errors.append(f"single -> {status}")
        except Exception as e:            # surface, never die silently
            errors.append(f"single -> {e!r}")

    start = time.monotonic()
    for t_arr, body in bodies:
        delay = start + t_arr - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        th = threading.Thread(target=client, args=(body,), daemon=True)
        th.start()
        threads.append(th)
    return threads


def _drive_sessions(router: FleetRouter, seed: int,
                    errors: List[str]) -> List[threading.Thread]:
    """Closed loop per session (a turn needs the previous reply), open
    loop across sessions (Poisson starts)."""
    rng = np.random.default_rng(seed + 1)
    starts = np.sort(rng.uniform(0.0, DURATION * 0.6, size=N_SESSIONS))

    def session(sid: int, start_at: float, words: int) -> None:
        time.sleep(start_at)
        msgs = [{"role": "user",
                 "content": " ".join(f"w{sid}t0i{j}"
                                     for j in range(words))}]
        for turn in range(N_TURNS):
            try:
                status, raw = _post(router.host, router.port,
                                    "/v1/chat/completions",
                                    {"messages": msgs, "max_tokens": 24,
                                     "session": sid})
                if status != 200:
                    errors.append(f"session {sid} turn {turn} -> {status}")
                    return
                reply = json.loads(raw)["choices"][0]["message"]
            except Exception as e:        # surface, never die silently
                errors.append(f"session {sid} turn {turn} -> {e!r}")
                return
            msgs.append({"role": reply["role"],
                         "content": reply["content"]})
            msgs.append({"role": "user",
                         "content": " ".join(f"w{sid}t{turn + 1}i{j}"
                                             for j in range(8))})

    threads = []
    for i, s in enumerate(starts):
        words = int(rng.integers(6, 18))
        th = threading.Thread(target=session,
                              args=(1000 + i, float(s), words),
                              daemon=True)
        th.start()
        threads.append(th)
    return threads


def run_arm(placer: str, seed: int = 0) -> Dict[str, Any]:
    fronts = _build_instances(seed0=seed)
    errors: List[str] = []
    try:
        with FleetRouter(tuple(f.url for f in fronts), placer=placer,
                         poll_interval=POLL_INTERVAL) as router:
            threads = _drive_singles(router, seed, errors)
            threads += _drive_sessions(router, seed, errors)
            for th in threads:
                th.join(timeout=120.0)
            stats = router.stats()
            health = router.health()
    finally:
        for f in fronts:
            f.shutdown()
    if errors:
        raise AssertionError(f"{placer}: {len(errors)} failed requests: "
                             f"{errors[:3]}")
    served = {u: int(v) for u, v in stats["served_tokens"].items()}
    return dict(
        placer=placer,
        n_requests=stats["n_requests"],
        placements=stats["placements"],
        served_tokens=served,
        total_served=sum(served.values()),
        imbalance=round(imbalance(served), 4),
        reprefill_tokens=stats["reprefill_tokens"],
        migrations=stats["migrations"],
        n_instances=health["n_instances"])


def main() -> None:
    print(f"[bench_fleet] {N_INSTANCES} instances x {len(ARMS)} arms, "
          f"rate={RATE}/s x {DURATION}s + {N_SESSIONS} sessions x "
          f"{N_TURNS} turns (smoke={SMOKE})", flush=True)
    rows = []
    for arm in ARMS:
        row = run_arm(arm)
        rows.append(row)
        print(f"[bench_fleet] {arm:>18}: {row['n_requests']} reqs, "
              f"imbalance {row['imbalance']:.3f}, "
              f"reprefill {row['reprefill_tokens']} tok, "
              f"served {row['total_served']} tok", flush=True)

    by = {r["placer"]: r for r in rows}
    rr, aff = by["round_robin"], by["retention_affinity"]
    os.makedirs(OUT_DIR, exist_ok=True)
    out = dict(
        meta=dict(n_instances=N_INSTANCES, time_scale=TIME_SCALE,
                  rate=RATE, duration=DURATION, n_sessions=N_SESSIONS,
                  n_turns=N_TURNS, smoke=SMOKE,
                  poll_interval=POLL_INTERVAL),
        arms=rows,
        asserts=dict(
            imbalance_affinity_le_round_robin=(
                aff["imbalance"] <= rr["imbalance"]),
            reprefill_affinity_le_round_robin=(
                aff["reprefill_tokens"] <= rr["reprefill_tokens"])))
    path = os.path.join(OUT_DIR, "BENCH_fleet.json")
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"[bench_fleet] wrote {path}")
    print("placer,n_requests,imbalance,reprefill_tokens,total_served")
    for r in rows:
        print(f"{r['placer']},{r['n_requests']},{r['imbalance']},"
              f"{r['reprefill_tokens']},{r['total_served']}")

    # ---- the PR 9 acceptance bar -------------------------------------
    # same workload, so total served tokens must agree across arms
    totals = [r["total_served"] for r in rows]
    assert max(totals) - min(totals) <= 0.02 * max(totals), \
        f"arms served different workloads: {totals}"
    # retention affinity must balance served tokens at least as well as
    # blind rotation...
    assert aff["imbalance"] <= rr["imbalance"], \
        (f"retention_affinity imbalance {aff['imbalance']} worse than "
         f"round_robin {rr['imbalance']}")
    # ...and pay less §3.3 re-prefill (round robin migrates nearly every
    # turn; the pin keeps sessions home)
    assert rr["reprefill_tokens"] > 0, \
        "round robin never migrated a session: workload too small"
    assert aff["reprefill_tokens"] <= rr["reprefill_tokens"], \
        (f"retention_affinity reprefill {aff['reprefill_tokens']} worse "
         f"than round_robin {rr['reprefill_tokens']}")
    print("[bench_fleet] PASS: retention_affinity <= round_robin on "
          "imbalance and reprefill_tokens")


if __name__ == "__main__":
    main()
