"""One benchmark function per paper table/figure (Figs. 8–22).

Each returns a list of row-dicts; ``benchmarks.run`` drives them all and
prints the summary CSV.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (DURATION, N_WORKERS, emit, fitted_estimator,
                               memory_estimator, run_sim)
from repro.cluster.trace import CODEFUSE, SHAREGPT, generate_trace, \
    length_distribution_summary
from repro.core.estimator import (a100_llama13b_hf_profile,
                                  a100_llama13b_profile)

RATES = (12, 16, 20, 24)


# ---------------------------------------------------------------------------
def bench_fig6_length_distribution() -> List[Dict]:
    rows = []
    for name, spec in (("codefuse", CODEFUSE), ("sharegpt", SHAREGPT)):
        t = generate_trace(20, DURATION, spec, seed=0)
        s = length_distribution_summary(t)
        s["workload"] = name
        rows.append(s)
    emit(rows, "fig6_length_distribution")
    return rows


def bench_fig8_10_estimator() -> List[Dict]:
    """Estimator fit error: per-iteration and 128-iteration RMSE (Fig. 10)."""
    rows = []
    rng = np.random.default_rng(0)
    for engine, prof in (("ds", a100_llama13b_profile),
                         ("hf", a100_llama13b_hf_profile)):
        true = prof()
        est = fitted_estimator(true, seed=3)
        # held-out grid
        grid = [(N, L) for N in (3, 6, 12, 24) for L in (48, 192, 768)]
        e1 = [est.tau_decode(L, N) - true.tau_decode(L, N) for N, L in grid]
        e128 = [est.t_serve(N, L, 128) - true.t_serve(N, L, 128) for N, L in grid]
        ep = [est.t_prefill(N, L) - true.t_prefill(N, L) for N, L in grid]
        rows.append(dict(engine=engine,
                         prefill_rmse_s=float(np.sqrt(np.mean(np.square(ep)))),
                         decode_iter_rmse_s=float(np.sqrt(np.mean(np.square(e1)))),
                         serve128_rmse_s=float(np.sqrt(np.mean(np.square(e128))))))
    emit(rows, "fig8_10_estimator_error")
    return rows


def bench_fig12_throughput() -> List[Dict]:
    """Throughput / latency percentiles / TTFT under various arrival rates
    (the online serving API made per-request TTFT and p50/p95/p99
    end-to-end latency observable — reported beyond the paper's columns)."""
    rows = []
    for engine in ("ds", "hf"):
        strategies = (("sls", "ils", "scls", "scls-cb") if engine == "ds"
                      else ("sls", "scls"))
        for rate in RATES:
            for s in strategies:
                m = run_sim(s, rate, engine=engine).metrics
                rows.append(dict(engine=engine, rate=rate, strategy=m.name,
                                 throughput=round(m.throughput, 3),
                                 mean_response_s=round(m.mean_response, 2),
                                 p50_response_s=round(m.p50_response, 2),
                                 p95_response_s=round(m.p95_response, 2),
                                 p99_response_s=round(m.p99_response, 2),
                                 ttft_mean_s=round(m.ttft_mean, 2),
                                 ttft_p95_s=round(m.ttft_p95, 2),
                                 # online-serving columns: offline trace
                                 # replay sheds nothing (0 / 1.0); the
                                 # admission sweep lives in bench_serving
                                 n_rejected=m.n_rejected,
                                 # per-reason shed counts (repro.obs):
                                 # Eq. 5–9 memory bound vs SLO deadline
                                 n_rejected_memory=m.n_rejected_memory,
                                 n_rejected_deadline=m.n_rejected_deadline,
                                 slo_attainment=round(m.slo_attainment, 4),
                                 # §3.3 rescheduling overhead, now measured
                                 # first-class (sim: analytic dense cost;
                                 # kv_retain="request" real runs report 0
                                 # for uninterrupted requests)
                                 reprefill_tokens=m.reprefill_tokens))
    emit(rows, "fig12_throughput_response")
    return rows


def bench_fig13_dive() -> List[Dict]:
    """Invalid tokens / batch size / pad tokens, SLS vs SCLS (Fig. 13)."""
    rows = []
    for engine in ("ds", "hf"):
        for rate in RATES:
            for s in ("sls", "scls"):
                m = run_sim(s, rate, engine=engine).metrics
                rows.append(dict(engine=engine, rate=rate, strategy=m.name,
                                 invalid_tokens=round(m.avg_invalid_tokens, 1),
                                 batch_size=round(m.avg_batch_size, 1),
                                 pad_tokens=round(m.avg_pad_tokens, 1)))
    emit(rows, "fig13_dive")
    return rows


def bench_fig14_overhead() -> List[Dict]:
    """Reschedule (slice) count distribution + early return ratio (Fig. 14)."""
    rows = []
    for rate in RATES:
        res = run_sim("scls", rate)
        sched = np.array([r.n_schedules for r in res.requests if r.done])
        hist = {f"slices_{i}": float(np.mean(sched == i)) for i in (1, 2, 3)}
        hist["slices_ge4"] = float(np.mean(sched >= 4))
        rows.append(dict(rate=rate, early_return_ratio=round(
            res.metrics.early_return_ratio, 4), **hist))
    emit(rows, "fig14_overhead")
    return rows


def bench_fig15_16_ablation() -> List[Dict]:
    """SO -> PM -> AB -> LB -> SCLS at rate 20 (Figs. 15-16)."""
    rows = []
    for engine in ("ds", "hf"):
        for s in ("sls", "so", "pm", "ab", "lb", "scls", "scls-cb"):
            m = run_sim(s, 20, engine=engine).metrics
            rows.append(dict(engine=engine, strategy=m.name,
                             throughput=round(m.throughput, 3),
                             mean_response_s=round(m.mean_response, 2),
                             p95_response_s=round(m.p95_response, 2),
                             invalid_tokens=round(m.avg_invalid_tokens, 1),
                             batch_size=round(m.avg_batch_size, 1),
                             pad_tokens=round(m.avg_pad_tokens, 1)))
    emit(rows, "fig15_16_ablation")
    return rows


def bench_fig17_load_balance() -> List[Dict]:
    """STD of instance completion time (Fig. 17)."""
    rows = []
    for rate in RATES:
        for s in ("sls", "ils", "scls"):
            m = run_sim(s, rate).metrics
            rows.append(dict(rate=rate, strategy=m.name,
                             ct_std_s=round(m.ct_std, 2)))
    emit(rows, "fig17_load_balance")
    return rows


def bench_fig18_21_slice_length() -> List[Dict]:
    """Slice-length sweep at rate 20 (Figs. 18-21)."""
    rows = []
    for S in (32, 64, 128, 256, 512):
        res = run_sim("scls", 20, slice_len=S)
        m = res.metrics
        sched = np.array([r.n_schedules for r in res.requests if r.done])
        rows.append(dict(slice_len=S,
                         throughput=round(m.throughput, 3),
                         mean_response_s=round(m.mean_response, 2),
                         p95_response_s=round(m.p95_response, 2),
                         invalid_tokens=round(m.avg_invalid_tokens, 1),
                         batch_size=round(m.avg_batch_size, 1),
                         pad_tokens=round(m.avg_pad_tokens, 1),
                         mean_slices=round(float(sched.mean()), 2),
                         early_return_ratio=round(m.early_return_ratio, 4),
                         ct_std_s=round(m.ct_std, 2)))
    emit(rows, "fig18_21_slice_length")
    return rows


def bench_fig22_scalability() -> List[Dict]:
    """Throughput vs #workers at rate 20 (Fig. 22)."""
    rows = []
    for engine in ("ds", "hf"):
        for w in (1, 2, 4, 8):
            m = run_sim("scls", 20, engine=engine, n_workers=w).metrics
            rows.append(dict(engine=engine, workers=w,
                             throughput=round(m.throughput, 3)))
    emit(rows, "fig22_scalability")
    return rows


def bench_beyond_paper() -> List[Dict]:
    """Beyond-paper comparisons: SCLS-CB (paper §7 future work, implemented)
    and ORACLE (perfect length predictor upper bound, cf. PiA/S³)."""
    rows = []
    for rate in (16, 24):
        for s in ("ils", "scls", "scls-cb", "oracle"):
            m = run_sim(s, rate).metrics
            rows.append(dict(rate=rate, strategy=m.name,
                             throughput=round(m.throughput, 3),
                             mean_response_s=round(m.mean_response, 2),
                             p95_response_s=round(m.p95_response, 2),
                             ct_std_s=round(m.ct_std, 2),
                             invalid_tokens=round(m.avg_invalid_tokens, 1),
                             pad_tokens=round(m.avg_pad_tokens, 1)))
    emit(rows, "beyond_paper")
    return rows


ALL_FIGURES = [
    bench_fig6_length_distribution,
    bench_fig8_10_estimator,
    bench_fig12_throughput,
    bench_fig13_dive,
    bench_fig14_overhead,
    bench_fig15_16_ablation,
    bench_fig17_load_balance,
    bench_fig18_21_slice_length,
    bench_fig22_scalability,
    bench_beyond_paper,
]
