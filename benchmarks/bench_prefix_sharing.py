"""Multi-turn COW prefix sharing (repro.kvcache + Session): prefill saved.

  PYTHONPATH=src python -m benchmarks.bench_prefix_sharing

Real JAX engines (reduced llama config) in the PR 5 retain mode, serving
the same N-conversation, 3-turn workload twice through the async Session
API — once with COW prefix sharing on, once off.  With sharing on, each
follow-up turn's history prefix joins the pages its previous turn left
resident (refcounted, no copy) instead of being re-prefilled, so the
engine computes only the new turn's tail.

Asserted, not just reported:

* token exactness — every turn's output stream is bit-identical between
  the shared and unshared runs (sharing must be invisible in tokens);
* prefix_hit_tokens > 0 with sharing on, == 0 off, and zero re-prefill;
* allocator hygiene — after every session closes, the page pool is back
  at its baseline (no leaked refcounts).

Emits bench_results/BENCH_prefix_sharing.json (CI uploads the artifact).
"""
from __future__ import annotations

import asyncio
import json
import os

from benchmarks.common import OUT_DIR

POOL_TOKENS = 1024
PAGE_TOKENS = 8
N_SESSIONS = 3
TURN_SIZES = (40, 12, 9)   # turn-1 prompt spans several full pages
GEN_LEN = 6


def _server(model, est, params, prefix_sharing: bool):
    from repro.engine.static_engine import StaticEngine
    from repro.serving import ServingConfig
    cfg = ServingConfig(strategy="scls", backend="real", workers=1,
                        kv_layout="paged", kv_retain="request",
                        page_tokens=PAGE_TOKENS, slice_len=8,
                        max_gen=2 * GEN_LEN, gamma=0.25, mem_bucket=8,
                        prefix_sharing=prefix_sharing)
    delta = model.kv_bytes_per_token()
    pool_pages = POOL_TOKENS // PAGE_TOKENS
    # scheduler budget == engine pool, as in the serve launcher
    mem = cfg.memory_estimator(
        delta, m_available=pool_pages * PAGE_TOKENS * delta / cfg.zeta + 1)
    assert mem.total_blocks == pool_pages
    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8,
                            kv_layout="paged", page_tokens=PAGE_TOKENS,
                            kv_pool_tokens=POOL_TOKENS,
                            prefix_sharing=prefix_sharing)]
    return cfg.build_real(engines, est, mem)


def bench_prefix_sharing(seed: int = 7):
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.engine.profiler import fit_estimator
    from repro.models.registry import get_model

    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 32), n_decode_iters=2,
                              repeats=1)
    rng = np.random.default_rng(seed)
    convs = [[rng.integers(2, arch.vocab_size, size=n).astype(np.int32)
              for n in TURN_SIZES]
             for _ in range(N_SESSIONS)]

    async def run(prefix_sharing: bool):
        server = _server(model, est, params, prefix_sharing).aio
        alloc = server.core.backend.allocators[0]
        baseline = alloc.free_blocks
        outs, submitted = [], 0
        async with server:
            for turns in convs:
                async with server.session(max_gen=2 * GEN_LEN) as s:
                    for t in turns:
                        h = await s.submit_turn(t, gen_len=GEN_LEN)
                        await h.result()
                        submitted += len(h.request.prompt)
                        outs.append(list(h.output_tokens))
            assert alloc.free_blocks == baseline, "leaked pages after close"
            assert not alloc.owners()
            m = await server.close()
        return outs, submitted, m

    rows, streams = [], {}
    for sharing in (True, False):
        outs, submitted, m = asyncio.run(run(sharing))
        streams[sharing] = outs
        assert m.n_completed == N_SESSIONS * len(TURN_SIZES)
        rows.append({"prefix_sharing": sharing,
                     "n_requests": m.n_completed,
                     "prompt_tokens_submitted": submitted,
                     "prefix_hit_tokens": m.prefix_hit_tokens,
                     "shared_blocks": m.shared_blocks,
                     "reprefill_tokens": m.reprefill_tokens,
                     "makespan_s": round(m.makespan, 4)})
        print(f"[bench_prefix_sharing] sharing={str(sharing):5s} "
              f"prompt_tokens={submitted:4d}  "
              f"prefix_hit={m.prefix_hit_tokens:4d}  "
              f"shared_blocks={m.shared_blocks:3d}  "
              f"makespan={m.makespan:6.2f} s")

    # sharing must be invisible in tokens but real in the allocator
    assert streams[True] == streams[False], \
        "prefix sharing must be token-exact vs the unshared run"
    by = {r["prefix_sharing"]: r for r in rows}
    assert by[True]["prefix_hit_tokens"] > 0, \
        "multi-turn sessions must actually hit the prefix index"
    assert by[True]["shared_blocks"] > 0
    assert by[False]["prefix_hit_tokens"] == 0
    assert by[True]["reprefill_tokens"] == 0

    hit = by[True]["prefix_hit_tokens"]
    submitted = by[True]["prompt_tokens_submitted"]
    saved = round(hit / submitted, 3)
    print(f"[bench_prefix_sharing] {hit}/{submitted} prompt tokens "
          f"({saved:.1%}) served from shared pages instead of prefill")
    out = {"rows": rows, "prefix_hit_tokens": hit,
           "prompt_tokens_submitted": submitted,
           "prefill_fraction_saved": saved, "token_exact": True}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_prefix_sharing.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[bench_prefix_sharing] -> {path}")
    return out


if __name__ == "__main__":
    bench_prefix_sharing()
