"""Benchmark driver: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig12,...]

Prints ``name,us_per_call,derived`` CSV lines per benchmark row (the
harness contract) and writes full CSVs under bench_results/.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_predictor import bench_predictor
    from benchmarks.bench_roofline import bench_roofline
    from benchmarks.figures import ALL_FIGURES

    only = None
    for a in sys.argv[1:]:
        if a.startswith("--only"):
            only = a.split("=", 1)[1].split(",") if "=" in a else None

    benches = list(ALL_FIGURES) + [bench_predictor, bench_kernels,
                                   bench_roofline]
    print("name,us_per_call,derived")
    for fn in benches:
        name = fn.__name__
        if only and not any(o in name for o in only):
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
            dt = (time.perf_counter() - t0) * 1e6
            derived = f"rows={len(rows)}"
            if rows and "throughput" in rows[0]:
                best = max(float(r["throughput"]) for r in rows)
                derived += f";best_thr={best}"
            if rows and "p99_response_s" in rows[0]:
                derived += (f";best_p99="
                            f"{min(float(r['p99_response_s']) for r in rows)}"
                            f";best_ttft="
                            f"{min(float(r['ttft_mean_s']) for r in rows)}")
            print(f"{name},{dt:.0f},{derived}")
        except Exception as e:  # keep the suite going
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
