"""Shared setup for the paper-figure benchmarks.

All cluster-scale figures run the real scheduler code through the
calibrated discrete-event simulator (8 LLaMA2-13B-profile workers, as in
the paper's testbed); engine-level figures run the real JAX engine on CPU
with reduced models.  Default durations are trimmed for CI; ``--full``
restores the paper's 600 s traces.
"""
from __future__ import annotations

import copy
import os
import sys
from typing import Dict, List

import numpy as np

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import CODEFUSE, generate_trace
from repro.core.estimator import (ServingTimeEstimator, a100_llama13b_profile,
                                  a100_llama13b_hf_profile)
from repro.core.memory import (A100_80GB_AVAILABLE, AnalyticMemoryEstimator,
                               LLAMA2_13B_DELTA, RuleBasedMemoryEstimator)
from repro.core.schedulers import make_strategy

FULL = "--full" in sys.argv
DURATION = 600.0 if FULL else 180.0
N_WORKERS = 8
OUT_DIR = os.environ.get("BENCH_OUT", "bench_results")

_PROFILES = {"ds": a100_llama13b_profile, "hf": a100_llama13b_hf_profile}
# paper §5.1: fixed batch size 12 (DS) / 16 (HF); Γ = 3 s (DS) / 6 s (HF)
_ENGINE_SETTINGS = {"ds": dict(fixed_batch_size=12, gamma=3.0),
                    "hf": dict(fixed_batch_size=16, gamma=6.0)}


def fitted_estimator(true_lat: ServingTimeEstimator, seed=0
                     ) -> ServingTimeEstimator:
    """'Profile' the ground-truth latency model with 2% measurement noise
    and fit Eq. 3/4 — mirrors the paper's one-time profiling."""
    rng = np.random.default_rng(seed)
    pre = [(N, L, true_lat.t_prefill(N, L) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    return est


def memory_estimator(engine: str):
    if engine == "ds":  # paper: rule table (Algorithm 2)
        return RuleBasedMemoryEstimator()
    return AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                   m_available=A100_80GB_AVAILABLE, zeta=0.9)


def run_sim(strategy_name: str, rate: float, engine: str = "ds",
            slice_len: int = 128, duration: float = None,
            n_workers: int = N_WORKERS, seed: int = 1, trace=None):
    duration = duration or DURATION
    true_lat = _PROFILES[engine]()
    est = fitted_estimator(true_lat)
    mem = memory_estimator(engine)
    es = _ENGINE_SETTINGS[engine]
    s = make_strategy(strategy_name, slice_len=slice_len,
                      fixed_batch_size=es["fixed_batch_size"],
                      gamma=es["gamma"], max_parallel=es["fixed_batch_size"])
    if trace is None:
        trace = generate_trace(rate, duration, CODEFUSE, seed=seed)
    sim = ClusterSimulator(s, n_workers, true_lat, est, mem,
                           noise_sigma=0.02, seed=seed + 1)
    return sim.run(copy.deepcopy(trace), duration)


def emit(rows: List[Dict], name: str) -> None:
    """Print rows and save a CSV under bench_results/."""
    if not rows:
        return
    os.makedirs(OUT_DIR, exist_ok=True)
    keys = list(rows[0].keys())
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    print(f"[{name}] -> {path}")
