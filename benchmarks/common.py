"""Shared setup for the paper-figure benchmarks.

All cluster-scale figures run the real scheduler code through the shared
``repro.serving`` stack (SliceServer → SchedulerCore → SimBackend) on
8 LLaMA2-13B-profile workers, as in the paper's testbed; engine-level
figures run the real JAX engine on CPU with reduced models.  Default
durations are trimmed for CI; ``--full`` restores the paper's 600 s
traces.
"""
from __future__ import annotations

import copy
import os
import sys
from typing import Dict, List, Optional

from repro.cluster.simulator import SimResult
from repro.cluster.trace import CODEFUSE, generate_trace
from repro.core.estimator import (a100_llama13b_hf_profile,
                                  a100_llama13b_profile)
from repro.core.memory import (A100_80GB_AVAILABLE, AnalyticMemoryEstimator,
                               LLAMA2_13B_DELTA, RuleBasedMemoryEstimator)
from repro.serving import ServingConfig, fitted_estimator

FULL = "--full" in sys.argv
DURATION = 600.0 if FULL else 180.0
N_WORKERS = 8
OUT_DIR = os.environ.get("BENCH_OUT", "bench_results")

_PROFILES = {"ds": a100_llama13b_profile, "hf": a100_llama13b_hf_profile}
# paper §5.1: fixed batch size 12 (DS) / 16 (HF); Γ = 3 s (DS) / 6 s (HF)
_ENGINE_SETTINGS = {"ds": dict(fixed_batch_size=12, gamma=3.0),
                    "hf": dict(fixed_batch_size=16, gamma=6.0)}


def memory_estimator(engine: str):
    if engine == "ds":  # paper: rule table (Algorithm 2)
        return RuleBasedMemoryEstimator()
    return AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                   m_available=A100_80GB_AVAILABLE, zeta=0.9)


def run_sim(strategy_name: str, rate: float, engine: str = "ds",
            slice_len: int = 128, duration: Optional[float] = None,
            n_workers: int = N_WORKERS, seed: int = 1,
            trace=None) -> SimResult:
    duration = duration or DURATION
    true_lat = _PROFILES[engine]()
    est = fitted_estimator(true_lat)
    mem = memory_estimator(engine)
    es = _ENGINE_SETTINGS[engine]
    cfg = ServingConfig(strategy=strategy_name, workers=n_workers,
                        slice_len=slice_len,
                        fixed_batch_size=es["fixed_batch_size"],
                        gamma=es["gamma"],
                        max_parallel=es["fixed_batch_size"],
                        noise_sigma=0.02, seed=seed + 1)
    if trace is None:
        trace = generate_trace(rate, duration, CODEFUSE, seed=seed)
    server = cfg.build_sim(true_lat, est, mem)
    reqs = copy.deepcopy(trace)
    server.replay(reqs)
    metrics = server.drain(duration)
    return SimResult(metrics, reqs,
                     [w.completion_time for w in server.core.workers],
                     server.core.batch_sizes)


def emit(rows: List[Dict], name: str) -> None:
    """Print rows and save a CSV under bench_results/."""
    if not rows:
        return
    os.makedirs(OUT_DIR, exist_ok=True)
    keys = list(rows[0].keys())
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    print(f"[{name}] -> {path}")
