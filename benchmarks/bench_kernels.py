"""Kernel micro-benchmarks: Pallas (interpret) vs XLA reference on CPU.

On this CPU container, interpret-mode timings measure the kernel *body
semantics*, not TPU performance — the roofline table (EXPERIMENTS.md) is
the performance source of truth.  This bench (a) proves the kernels run,
(b) times the XLA reference path that the engines actually execute on CPU,
(c) times the PR-10 fused RoPE+paged-KV arms against their unfused
multi-pass pipelines, asserting token-exactness against the jnp oracles,
and emits bench_results/BENCH_kernels.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import json
import os
import time
from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.kernels import ops
from repro.models.common import apply_rope


def _time(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # compile
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def bench_kernels() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for (B, T, Hq, Hkv, D) in [(4, 128, 8, 2, 64), (2, 512, 8, 8, 64)]:
        q = jax.random.normal(key, (B, T, Hq, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
        us_xla = _time(ops.prefill_attention, q, k, v, pos, impl="xla")
        rows.append(dict(kernel="flash_prefill", shape=f"B{B}xT{T}xH{Hq}kv{Hkv}xD{D}",
                         impl="xla_ref", us_per_call=round(us_xla, 1)))
    for (B, W, Hq, Hkv, D) in [(8, 1024, 8, 2, 64), (32, 2048, 8, 1, 64)]:
        kc = jax.random.normal(key, (B, W, Hkv, D))
        vc = jax.random.normal(jax.random.fold_in(key, 3), (B, W, Hkv, D))
        qd = jax.random.normal(jax.random.fold_in(key, 4), (B, Hq, D))
        slot_pos = jnp.broadcast_to(jnp.arange(W)[None], (B, W)).astype(jnp.int32)
        q_pos = jnp.full((B,), W - 1, jnp.int32)
        us_xla = _time(ops.decode_gqa_attention, qd, kc, vc, slot_pos, q_pos,
                       impl="xla")
        rows.append(dict(kernel="decode_attention", shape=f"B{B}xW{W}xH{Hq}kv{Hkv}xD{D}",
                         impl="xla_ref", us_per_call=round(us_xla, 1)))
    # interpret-mode correctness spot check (tiny shape; slow by design)
    q = jax.random.normal(key, (1, 32, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 5), (1, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 6), (1, 32, 2, 16))
    pos = jnp.arange(32)[None].astype(jnp.int32)
    a = ops.prefill_attention(q, k, v, pos, impl="pallas", block_q=8, block_k=8)
    b = ops.prefill_attention(q, k, v, pos, impl="xla")
    rows.append(dict(kernel="flash_prefill", shape="pallas_interp_check",
                     impl="pallas", us_per_call=float(jnp.abs(a - b).max())))
    rows += bench_fused_kernels()
    emit(rows, "kernels")
    summary = _fused_summary(rows)
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump({"rows": rows, "fused_vs_unfused": summary}, f, indent=2)
    print(f"[bench_kernels] -> {path}")
    return rows


# ---------------------------------------------------------------------------
# PR 10: fused RoPE + paged-KV arms vs their unfused multi-pass pipelines
# ---------------------------------------------------------------------------
def _fused_write_inputs(B, T, pg, Hkv, D, seed=7):
    """Full left-aligned prefill rows over disjoint block tables (the
    allocator contract: only null page 0 is ever shared)."""
    key = jax.random.PRNGKey(seed)
    nb = T // pg
    P = B * nb + 1
    k_new = jax.random.normal(key, (B, T, Hkv, D))
    v_new = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    bt = jnp.asarray(np.random.default_rng(seed)
                     .permutation(np.arange(1, P)).reshape(B, nb), jnp.int32)
    kp = jax.random.normal(jax.random.fold_in(key, 2), (P, pg, Hkv, D))
    vp = jax.random.normal(jax.random.fold_in(key, 3), (P, pg, Hkv, D))
    return k_new, v_new, pos, bt, kp, vp


def _fused_decode_inputs(B, W, pg, Hq, Hkv, D, seed=9):
    """Mid-decode pool: W resident tokens per row, new token at slot W."""
    key = jax.random.PRNGKey(seed)
    nb = -(-(W + 1) // pg)
    P = B * nb + 1
    kp = jax.random.normal(key, (P, pg, Hkv, D))
    vp = jax.random.normal(jax.random.fold_in(key, 1), (P, pg, Hkv, D))
    bt = jnp.asarray(np.random.default_rng(seed)
                     .permutation(np.arange(1, P)).reshape(B, nb), jnp.int32)
    slot_pos = np.full((B, nb * pg), -1, np.int32)
    slot_pos[:, :W + 1] = np.arange(W + 1)
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, Hq, D))
    kn = jax.random.normal(jax.random.fold_in(key, 3), (B, Hkv, D))
    vn = jax.random.normal(jax.random.fold_in(key, 4), (B, Hkv, D))
    s = jnp.full((B,), W, jnp.int32)
    return q, kn, vn, bt, jnp.asarray(slot_pos), s, s, kp, vp


@jax.jit
def _unfused_write_two_pass(k_new, v_new, pos, bt, kp, vp):
    """The pre-PR-10 pipeline: a jnp RoPE pass over prefill K, then a
    separate paged scatter — two reads of K, one of the pool."""
    k_rot = apply_rope(k_new, jnp.maximum(pos, 0), 10000.0)
    return ops.paged_prefill_write(k_rot, v_new, pos, bt, kp, vp, impl="xla")


@partial(jax.jit, static_argnames=("pg",))
def _unfused_decode_three_pass(q, kn, vn, bt, slot_pos, slots, q_pos, kp, vp,
                               pg):
    """The pre-PR-10 pipeline: rotate q/k, scatter the token's K/V into
    its page slot, then run paged decode attention — three launches."""
    qr = apply_rope(q[:, None], q_pos[:, None], 10000.0)[:, 0]
    kr = apply_rope(kn[:, None], q_pos[:, None], 10000.0)[:, 0]
    pages = bt[jnp.arange(q.shape[0]), slots // pg]
    uk = kp.at[pages, slots % pg].set(kr)
    uv = vp.at[pages, slots % pg].set(vn)
    out = ops.paged_decode_attention(qr, uk, uv, bt, slot_pos, q_pos,
                                     impl="xla")
    return out, uk, uv


def bench_fused_kernels() -> List[Dict]:
    rows = []
    for (B, T, pg, Hkv, D) in [(4, 256, 16, 2, 64), (2, 1024, 16, 8, 64)]:
        args = _fused_write_inputs(B, T, pg, Hkv, D)
        fused = partial(ops.fused_rope_prefill_write, impl="xla")
        # token-exactness: the one-pass fusion vs the two-pass pipeline,
        # both ultimately pinned to the jnp oracle (impl="xla" IS
        # ref.fused_rope_prefill_write_ref)
        fk, fv = fused(*args)
        uk, uv = _unfused_write_two_pass(*args)
        assert np.allclose(np.asarray(fk), np.asarray(uk), atol=2e-5), \
            "fused prefill write diverged from the unfused two-pass K"
        assert np.array_equal(np.asarray(fv), np.asarray(uv)), \
            "fused prefill write must leave V bit-exact"
        shape = f"B{B}xT{T}xpg{pg}xkv{Hkv}xD{D}"
        rows.append(dict(kernel="fused_rope_prefill_write", shape=shape,
                         impl="xla_unfused_2pass",
                         us_per_call=round(_time(_unfused_write_two_pass,
                                                 *args), 1)))
        rows.append(dict(kernel="fused_rope_prefill_write", shape=shape,
                         impl="xla_fused",
                         us_per_call=round(_time(fused, *args), 1)))
    for (B, W, pg, Hq, Hkv, D) in [(8, 1023, 16, 8, 2, 64),
                                   (32, 2047, 16, 8, 1, 64)]:
        args = _fused_decode_inputs(B, W, pg, Hq, Hkv, D)
        fused = partial(ops.fused_rope_decode_append, impl="xla")
        fo, fk, fv = fused(*args)
        uo, uk, uv = _unfused_decode_three_pass(*args, pg=pg)
        assert np.allclose(np.asarray(fo), np.asarray(uo), atol=2e-5), \
            "fused decode append diverged from the unfused attention output"
        assert np.allclose(np.asarray(fk), np.asarray(uk), atol=2e-5)
        assert np.array_equal(np.asarray(fv), np.asarray(uv)), \
            "fused decode append must leave V bit-exact"
        shape = f"B{B}xW{W}xpg{pg}xH{Hq}kv{Hkv}xD{D}"
        rows.append(dict(kernel="fused_rope_decode_append", shape=shape,
                         impl="xla_unfused_3pass",
                         us_per_call=round(_time(_unfused_decode_three_pass,
                                                 *args, pg=pg), 1)))
        rows.append(dict(kernel="fused_rope_decode_append", shape=shape,
                         impl="xla_fused",
                         us_per_call=round(_time(fused, *args), 1)))
    # interpret-mode kernel-body checks vs the jnp oracles (tiny shapes)
    wargs = _fused_write_inputs(2, 16, 8, 2, 16)
    pk, pv = ops.fused_rope_prefill_write(*wargs, impl="pallas")
    ok, ov = ops.fused_rope_prefill_write(*wargs, impl="xla")
    err = max(float(jnp.abs(pk - ok).max()), float(jnp.abs(pv - ov).max()))
    assert err < 2e-5, f"fused prefill write pallas body drifted: {err}"
    rows.append(dict(kernel="fused_rope_prefill_write",
                     shape="pallas_interp_check", impl="pallas",
                     us_per_call=err))
    dargs = _fused_decode_inputs(2, 15, 8, 4, 2, 16)
    po, pk, pv = ops.fused_rope_decode_append(*dargs, impl="pallas")
    oo, ok, ov = ops.fused_rope_decode_append(*dargs, impl="xla")
    err = max(float(jnp.abs(po - oo).max()), float(jnp.abs(pk - ok).max()),
              float(jnp.abs(pv - ov).max()))
    assert err < 2e-5, f"fused decode append pallas body drifted: {err}"
    rows.append(dict(kernel="fused_rope_decode_append",
                     shape="pallas_interp_check", impl="pallas",
                     us_per_call=err))
    return rows


def _fused_summary(rows: List[Dict]) -> List[Dict]:
    out = []
    for kernel in ("fused_rope_prefill_write", "fused_rope_decode_append"):
        shapes = {r["shape"] for r in rows
                  if r["kernel"] == kernel and r["impl"].startswith("xla_")}
        for shape in sorted(shapes):
            sub = {r["impl"]: r["us_per_call"] for r in rows
                   if r["kernel"] == kernel and r["shape"] == shape}
            unfused = next(v for k, v in sub.items() if "unfused" in k)
            out.append({"kernel": kernel, "shape": shape,
                        "unfused_us": unfused, "fused_us": sub["xla_fused"],
                        "speedup": round(unfused / max(sub["xla_fused"],
                                                       1e-9), 3)})
    return out


if __name__ == "__main__":
    for r in bench_kernels():
        print(f"[bench_kernels] {r['kernel']:26s} {r['shape']:24s} "
              f"{r['impl']:18s} {r['us_per_call']}")
