"""Kernel micro-benchmarks: Pallas (interpret) vs XLA reference on CPU.

On this CPU container, interpret-mode timings measure the kernel *body
semantics*, not TPU performance — the roofline table (EXPERIMENTS.md) is
the performance source of truth.  This bench (a) proves the kernels run,
(b) times the XLA reference path that the engines actually execute on CPU.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops


def _time(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # compile
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def bench_kernels() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for (B, T, Hq, Hkv, D) in [(4, 128, 8, 2, 64), (2, 512, 8, 8, 64)]:
        q = jax.random.normal(key, (B, T, Hq, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
        us_xla = _time(ops.prefill_attention, q, k, v, pos, impl="xla")
        rows.append(dict(kernel="flash_prefill", shape=f"B{B}xT{T}xH{Hq}kv{Hkv}xD{D}",
                         impl="xla_ref", us_per_call=round(us_xla, 1)))
    for (B, W, Hq, Hkv, D) in [(8, 1024, 8, 2, 64), (32, 2048, 8, 1, 64)]:
        kc = jax.random.normal(key, (B, W, Hkv, D))
        vc = jax.random.normal(jax.random.fold_in(key, 3), (B, W, Hkv, D))
        qd = jax.random.normal(jax.random.fold_in(key, 4), (B, Hq, D))
        slot_pos = jnp.broadcast_to(jnp.arange(W)[None], (B, W)).astype(jnp.int32)
        q_pos = jnp.full((B,), W - 1, jnp.int32)
        us_xla = _time(ops.decode_gqa_attention, qd, kc, vc, slot_pos, q_pos,
                       impl="xla")
        rows.append(dict(kernel="decode_attention", shape=f"B{B}xW{W}xH{Hq}kv{Hkv}xD{D}",
                         impl="xla_ref", us_per_call=round(us_xla, 1)))
    # interpret-mode correctness spot check (tiny shape; slow by design)
    q = jax.random.normal(key, (1, 32, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 5), (1, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 6), (1, 32, 2, 16))
    pos = jnp.arange(32)[None].astype(jnp.int32)
    a = ops.prefill_attention(q, k, v, pos, impl="pallas", block_q=8, block_k=8)
    b = ops.prefill_attention(q, k, v, pos, impl="xla")
    rows.append(dict(kernel="flash_prefill", shape="pallas_interp_check",
                     impl="pallas", us_per_call=float(jnp.abs(a - b).max())))
    emit(rows, "kernels")
    return rows
