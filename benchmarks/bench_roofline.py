"""Roofline table assembly: reads dryrun_results/*.json (produced by
``python -m repro.launch.dryrun --all``) into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit


def bench_roofline(results_dir: str = "dryrun_results") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = rec.get("roofline", {})
        rows.append(dict(
            arch=rec["arch"], shape=rec["shape"],
            mesh="x".join(str(v) for v in rec["mesh"].values()),
            compute_s=f"{r.get('compute_s', 0):.3e}",
            memory_s=f"{r.get('memory_s', 0):.3e}",
            collective_s=f"{r.get('collective_s', 0):.3e}",
            dominant=rec.get("dominant", "?"),
            useful_flop_ratio=(f"{rec['useful_flop_ratio']:.3f}"
                               if rec.get("useful_flop_ratio") else "-"),
            compile_s=rec.get("compile_s", "-"),
        ))
    if rows:
        emit(rows, "roofline")
    else:
        print("[roofline] no dryrun_results/*.json yet — run "
              "`python -m repro.launch.dryrun --all` first")
    return rows
