"""Paged vs. dense KV layout (repro.kvcache): parallelism and throughput.

  PYTHONPATH=src python -m benchmarks.bench_paged [--full] [--real]

Two testbeds, both in the PR-1 memory-constrained regime (KV capacity
binds the batch size long before compute does):

1. Cluster simulator (default, seconds): LLaMA2-13B profile with a ~6 GB
   KV budget per worker.  A dense continuous-batching worker must reserve
   the worst-case context (max_input + max_gen ≈ 2048 slots) per engine
   slot, so its parallelism cap is budget // worst_case — the conservative
   cap the paper criticizes ILS for.  The paged layout admits by *actual*
   free blocks against each request's envelope:

     ils-dense     — conservative slot cap (worst-case contiguous regions)
     ils-paged     — block-granular admission, envelope = input + max_gen
     scls-cb-paged — slice leases: envelope = input + S (Eq. 5), the tight
                     slice bound finally realized at the allocator

   Expected: peak parallelism and throughput strictly increase down the
   ladder.

2. Real JAX engines (--real, ~a minute): two ContinuousEngines on the
   reduced llama config with the *same* KV-token budget — dense spends it
   on max_slots worst-case rows, paged on a page pool — serving identical
   prompts.  Token outputs are identical (tested in tests/test_engine.py);
   the paged engine sustains strictly higher peak parallelism and drains
   the workload in fewer iterations.

3. KV retention (--real, PR 5): the same multi-slice workload through the
   real SCLS backend with kv_retain="slice" (classic §3.3 re-prefill at
   every reschedule) vs kv_retain="request" (persistent paged StaticEngine
   storage: prefix pages survive, a resumed slice remaps its block table
   and prefills nothing).  Reports re-prefill tokens saved and mean
   per-slice latency; token streams are asserted identical and
   reprefill_tokens == 0 for the retained run.  Emits
   bench_results/BENCH_paged_retain.json (CI uploads it as an artifact).

4. Batch packing (default, PR 10): the Eq. 5–9 batch-max bound vs the
   envelope-exact per-request block sum under the same paged budget —
   peak admissible parallelism (asserted strictly higher) plus an
   end-to-end sim ladder.  Emits bench_results/BENCH_paged.json.
"""
from __future__ import annotations

import copy
import sys

from benchmarks.common import DURATION, OUT_DIR, emit, fitted_estimator
from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import WORKLOADS, generate_trace
from repro.core.estimator import a100_llama13b_profile
from repro.core.memory import (AnalyticMemoryEstimator, LLAMA2_13B_DELTA,
                               PagedMemoryEstimator)
from repro.core.schedulers import make_strategy

# memory-constrained testbed (PR 1): ~6 GB KV budget instead of the A100's
# 50 GB, so admission is memory-bound and the layout decides parallelism
MEM_AVAILABLE = 6e9
RATE = 24.0
N_WORKERS = 4
PAGE_TOKENS = 16
ZETA = 0.9
SLICE = 128
MAX_GEN = 1024
MAX_INPUT = 1024  # workload cap (cluster.trace.WorkloadSpec)


def _dense_slot_cap() -> int:
    """Parallelism a dense worker can promise: worst-case contiguous
    (max_input + max_gen) slots per engine row, as ContinuousEngine
    reserves with kv_layout="dense"."""
    worst = (MAX_INPUT + MAX_GEN) * LLAMA2_13B_DELTA
    return max(1, int(ZETA * MEM_AVAILABLE // worst))


def bench_paged_sim(duration: float = None, rate: float = RATE,
                    n_workers: int = N_WORKERS, seed: int = 1):
    duration = duration or DURATION
    true_lat = a100_llama13b_profile()
    est = fitted_estimator(true_lat)
    dense_cap = _dense_slot_cap()
    variants = (
        ("ils-dense", "ils", dict(max_parallel=dense_cap), "dense"),
        ("ils-paged", "ils", dict(max_parallel=1 << 30), "paged"),
        ("scls-cb-paged", "scls-cb", {}, "paged"),
    )
    rows = []
    for wl_name, spec in WORKLOADS.items():
        trace = generate_trace(rate, duration, spec, seed=seed)
        for label, strat, kw, layout in variants:
            if layout == "paged":
                mem = PagedMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                           m_available=MEM_AVAILABLE,
                                           page_tokens=PAGE_TOKENS, zeta=ZETA)
            else:
                mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                              m_available=MEM_AVAILABLE,
                                              zeta=ZETA)
            s = make_strategy(strat, slice_len=SLICE, max_gen=MAX_GEN,
                              gamma=3.0, kv_layout=layout, **kw)
            sim = ClusterSimulator(s, n_workers, true_lat, est, mem,
                                   noise_sigma=0.02, seed=seed + 1)
            res = sim.run(copy.deepcopy(trace), duration)
            m = res.metrics
            rows.append({
                "workload": wl_name,
                "variant": label,
                "throughput": round(m.throughput, 4),
                "peak_parallel": sim.peak_parallel,
                "avg_batch_size": round(m.avg_batch_size, 2),
                "mean_response": round(m.mean_response, 2),
                "p95_response": round(m.p95_response, 2),
                "n_completed": m.n_completed,
            })
            print(f"[bench_paged] {wl_name:9s} {label:14s} "
                  f"thr={m.throughput:6.3f} req/s  "
                  f"peak_parallel={sim.peak_parallel:3d}  "
                  f"resp={m.mean_response:6.1f}s")
    emit(rows, "bench_paged")
    for wl_name in WORKLOADS:
        sub = {r["variant"]: r for r in rows if r["workload"] == wl_name}
        assert (sub["ils-paged"]["peak_parallel"]
                > sub["ils-dense"]["peak_parallel"]), \
            f"{wl_name}: paged must beat the dense slot cap"
        assert (sub["scls-cb-paged"]["peak_parallel"]
                > sub["ils-paged"]["peak_parallel"]), \
            f"{wl_name}: slice leases must pack tighter than full envelopes"
    return rows


def bench_paged_real(n_requests: int = 12, seed: int = 3):
    """Same byte budget, real engines: dense rows vs. a page pool."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.engine.continuous_engine import ContinuousEngine
    from repro.models.registry import get_model

    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    sizes = rng.integers(3, 14, size=n_requests)
    prompts = [rng.integers(2, cfg.vocab_size, size=int(s)).astype(np.int32)
               for s in sizes]
    forced = [int(g) for g in rng.integers(3, 8, size=n_requests)]
    W, budget = 64, 4 * 64  # dense: 4 worst-case rows; paged: 32 x 8-token pages
    dense = ContinuousEngine(model, params, max_slots=budget // W,
                             max_context=W, eos_id=1, len_bucket=8)
    paged = ContinuousEngine(model, params, max_slots=n_requests,
                             max_context=W, eos_id=1, len_bucket=8,
                             kv_layout="paged", page_tokens=8,
                             total_kv_tokens=budget)
    rd = dense.serve(prompts, forced_gen_lens=forced)
    rp = paged.serve(prompts, forced_gen_lens=forced)
    assert rp.outputs == rd.outputs, "paged engine must be token-exact"
    assert rp.peak_parallel > rd.peak_parallel
    assert rp.iterations < rd.iterations
    rows = [{"engine": name, "kv_tokens": budget,
             "peak_parallel": r.peak_parallel,
             "mean_parallel": round(r.mean_parallel, 2),
             "iterations": r.iterations,
             "tokens_per_iter": round(sum(map(len, r.outputs)) / r.iterations, 2)}
            for name, r in (("dense", rd), ("paged", rp))]
    for r in rows:
        print(f"[bench_paged:real] {r['engine']:5s} "
              f"peak_parallel={r['peak_parallel']:2d}  "
              f"iters={r['iterations']:3d}  "
              f"tokens/iter={r['tokens_per_iter']}")
    emit(rows, "bench_paged_real")
    return rows


def bench_paged_retain(n_requests: int = 8, gen_len: int = 24,
                       slice_len: int = 8, seed: int = 5):
    """kv_retain="slice" vs "request" on the real backend: same workload,
    same budget — retention eliminates the §3.3 re-prefill entirely."""
    import json
    import os

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.engine.static_engine import StaticEngine
    from repro.models.registry import get_model
    from repro.serving import ServingConfig

    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.engine.profiler import fit_estimator
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 64), n_decode_iters=2,
                              repeats=1)
    rng = np.random.default_rng(seed)
    # multi-slice regime where re-prefill dominates: prompts much longer
    # than the slice, gen spanning >= 3 slices
    sizes = rng.integers(48, 128, size=n_requests)
    prompts = [rng.integers(2, cfg.vocab_size, size=int(s)).astype(np.int32)
               for s in sizes]
    page_tokens = 16
    delta = model.kv_bytes_per_token()
    rows, streams = [], {}
    for retain in ("slice", "request"):
        scfg = ServingConfig(strategy="scls", backend="real",
                             kv_layout="paged", page_tokens=page_tokens,
                             kv_retain=retain, slice_len=slice_len,
                             max_gen=2 * gen_len, gamma=0.25,
                             m_available=delta * 16384, mem_bucket=8,
                             workers=1)
        mem = scfg.memory_estimator(delta)
        if retain == "request":
            engines = [StaticEngine(model, params, eos_id=1, len_bucket=8,
                                    kv_layout="paged",
                                    page_tokens=page_tokens,
                                    kv_pool_tokens=mem.total_blocks
                                    * page_tokens)]
        else:
            engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)]
        server = scfg.build_real(engines, est, mem)
        handles = [server.submit(p, gen_len=gen_len, max_gen=2 * gen_len,
                                 arrival=0.05 * i)
                   for i, p in enumerate(prompts)]
        m = server.drain()
        assert m.n_completed == n_requests
        streams[retain] = [h.request.output_tokens for h in handles]
        n_batches = server.core.total_batches
        per_slice = m.makespan / max(n_batches, 1)
        rows.append({"kv_retain": retain,
                     "n_requests": n_requests,
                     "gen_len": gen_len,
                     "slice_len": slice_len,
                     "n_slices": n_batches,
                     "reprefill_tokens": m.reprefill_tokens,
                     "makespan_s": round(m.makespan, 4),
                     "per_slice_latency_s": round(per_slice, 5),
                     "throughput": round(m.throughput, 3)})
        print(f"[bench_paged:retain] {retain:7s} "
              f"reprefill={m.reprefill_tokens:5d} tok  "
              f"per_slice={per_slice*1e3:7.1f} ms  "
              f"makespan={m.makespan:6.2f} s")
    by = {r["kv_retain"]: r for r in rows}
    assert streams["slice"] == streams["request"], \
        "retention must be token-exact vs the dense re-prefill path"
    assert by["request"]["reprefill_tokens"] == 0, \
        "uninterrupted retained requests must never re-prefill"
    assert by["slice"]["reprefill_tokens"] > 0
    saved = by["slice"]["reprefill_tokens"]
    speedup = (by["slice"]["per_slice_latency_s"]
               / max(by["request"]["per_slice_latency_s"], 1e-9))
    print(f"[bench_paged:retain] saved {saved} re-prefill tokens, "
          f"per-slice speedup x{speedup:.2f}")
    out = {"rows": rows, "reprefill_tokens_saved": saved,
           "per_slice_speedup": round(speedup, 3)}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_paged_retain.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[bench_paged:retain] -> {path}")
    return out


def bench_paged_packing(duration: float = None, rate: float = RATE,
                        n_workers: int = N_WORKERS, seed: int = 1):
    """Eq. 5–9 batch-max bound vs PR-10 envelope-exact packing, same paged
    budget (bench_results/BENCH_paged.json).

    Two measurements per workload:

    1. Peak admissible parallelism (deterministic): the largest batch the
       memory bound admits from one sorted burst backlog.  Batch-max
       charges every member the longest envelope (N x blocks_max), the
       envelope mode the exact per-request sum — so its feasible set is a
       strict superset and the peak batch is asserted strictly higher.
    2. Sim ladder: the same open-loop trace through the central SCLS
       scheduler under each packing mode; Algorithm 1 stays time-optimal
       over the (larger) feasible set, so total estimated time — and in
       practice throughput — only improves.
    """
    import json
    import os
    duration = duration or DURATION
    true_lat = a100_llama13b_profile()
    est = fitted_estimator(true_lat)
    from repro.core.batcher import dp_batch

    def _mem():
        return PagedMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                    m_available=MEM_AVAILABLE,
                                    page_tokens=PAGE_TOKENS, zeta=ZETA)

    rows, sim_rows = [], []
    for wl_name, spec in WORKLOADS.items():
        # -- 1. largest bound-admissible batch on one burst backlog ------
        burst = sorted(generate_trace(60.0, 5.0, spec, seed=seed),
                       key=lambda r: r.effective_input_len)
        mem = _mem()
        peak = {}
        n_bm = 0
        for N in range(1, len(burst) + 1):
            if not mem.fits(N, burst[N - 1].effective_input_len, SLICE):
                break
            n_bm = N
        peak["batch-max"] = n_bm
        n_env, total = 0, 0
        for N, r in enumerate(burst, 1):
            total += mem.blocks_per_request(r.effective_input_len, SLICE)
            if not mem.fits_envelope(total):
                break
            n_env = N
        peak["envelope"] = n_env
        t_part = {p: sum(b.est_time for b in
                         dp_batch(list(burst), SLICE, est, _mem(), packing=p))
                  for p in ("batch-max", "envelope")}
        for p in ("batch-max", "envelope"):
            rows.append({"workload": wl_name, "packing": p,
                         "backlog": len(burst),
                         "peak_admissible_batch": peak[p],
                         "partition_est_time_s": round(t_part[p], 3)})
            print(f"[bench_paged:packing] {wl_name:9s} {p:9s} "
                  f"peak_admissible={peak[p]:3d}  "
                  f"partition_time={t_part[p]:8.3f}s")
        assert peak["envelope"] > peak["batch-max"], \
            f"{wl_name}: the exact envelope sum must admit a strictly " \
            f"larger peak batch than N x blocks_max under the same budget"
        assert t_part["envelope"] <= t_part["batch-max"] + 1e-9, \
            f"{wl_name}: a superset feasible set cannot cost the DP time"
        # -- 2. end-to-end sim ladder ------------------------------------
        trace = generate_trace(rate, duration, spec, seed=seed)
        for packing in ("batch-max", "envelope"):
            s = make_strategy("scls", slice_len=SLICE, max_gen=MAX_GEN,
                              gamma=3.0, kv_layout="paged", packing=packing)
            sim = ClusterSimulator(s, n_workers, true_lat, est, _mem(),
                                   noise_sigma=0.02, seed=seed + 1)
            m = sim.run(copy.deepcopy(trace), duration).metrics
            sim_rows.append({"workload": wl_name, "packing": packing,
                             "throughput": round(m.throughput, 4),
                             "peak_parallel": sim.peak_parallel,
                             "avg_batch_size": round(m.avg_batch_size, 2),
                             "mean_response": round(m.mean_response, 2),
                             "n_completed": m.n_completed})
            print(f"[bench_paged:packing] {wl_name:9s} {packing:9s} "
                  f"thr={m.throughput:6.3f} req/s  "
                  f"peak_parallel={sim.peak_parallel:3d}")
    emit(rows, "bench_paged_packing_admissible")
    emit(sim_rows, "bench_paged_packing_sim")
    out = {"peak_admissible": rows, "sim": sim_rows}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_paged.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[bench_paged:packing] -> {path}")
    return out


if __name__ == "__main__":
    if "--retain-only" not in sys.argv:
        bench_paged_sim()
        bench_paged_packing()
    if "--real" in sys.argv or "--retain-only" in sys.argv:
        if "--retain-only" not in sys.argv:
            bench_paged_real()
        bench_paged_retain()
