#!/usr/bin/env python
"""Observability artifact validator (CI obs-smoke job).

Checks that a ``--trace-out`` Chrome trace-event JSON is structurally
valid (loadable by Perfetto / chrome://tracing) and that a Prometheus
text exposition parses with the histogram invariants intact.  Importable
by ``tests/test_obs.py`` — the CI job and the test suite share one
definition of "valid".

  PYTHONPATH=src python scripts/validate_obs.py trace.json \
      [--metrics metrics.txt] [--decisions trace.decisions.json]

Exits non-zero listing every violation found.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
from typing import Dict, List, Tuple

#: event phases the serving tracer emits (subset of the trace-event spec)
KNOWN_PHASES = {"X", "i", "C", "b", "e", "M"}
#: first worker-row thread id — mirrors repro.obs.trace.worker_tid(0);
#: duplicated so this validator runs without PYTHONPATH=src (CI curls and
#: validates from a bare checkout)
TID_WORKER_BASE = 100


# ---------------------------------------------------------------------------
# Chrome trace-event JSON
# ---------------------------------------------------------------------------
def validate_trace(trace: dict) -> List[str]:
    """Structural errors in a trace-event JSON object ([] = valid)."""
    errors: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' list"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        for field in ("name", "pid"):
            if field not in ev:
                errors.append(f"{where} ({ph}): missing {field!r}")
        if ph == "M":
            continue  # metadata has no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) \
                or ts < 0:
            errors.append(f"{where} ({ev.get('name')}): bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) \
                    or not math.isfinite(dur) or dur < 0:
                errors.append(f"{where} ({ev.get('name')}): "
                              f"bad dur {dur!r}")
        if ph == "C":
            if not isinstance(ev.get("args"), dict) or not ev["args"]:
                errors.append(f"{where} ({ev.get('name')}): counter "
                              f"without args")
        if ph in ("b", "e") and "id" not in ev:
            errors.append(f"{where} ({ev.get('name')}): async span "
                          f"without id")
    # every opened async span must be closed (request lifecycles end at
    # finalize; an unbalanced trace means a request leaked)
    opened: Dict[Tuple[str, int], int] = {}
    for ev in events:
        if not isinstance(ev, dict) or "id" not in ev:
            continue
        key = (ev.get("name"), ev["id"])
        if ev.get("ph") == "b":
            opened[key] = opened.get(key, 0) + 1
        elif ev.get("ph") == "e":
            opened[key] = opened.get(key, 0) - 1
    for (name, aid), n in sorted(opened.items()):
        if n != 0:
            errors.append(f"async span {name!r} id={aid} "
                          f"{'never closed' if n > 0 else 'closed twice'}")
    return errors


def trace_slice_log(trace: dict) -> List[list]:
    """Reconstruct the scheduler dispatch log from a trace's slice spans.

    Returns entries shaped exactly like ``SchedulerCore.batch_log``:
    ``["static", wid, rids, input_len, slice_len]`` for each ``slice``
    span and ``["cont", wid, rids]`` for each ``cont`` span, in emission
    order — what the golden bit-exactness test compares.
    """
    out: List[list] = []
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        wid = ev["tid"] - TID_WORKER_BASE
        a = ev.get("args", {})
        if ev["name"] == "slice":
            out.append(["static", wid, list(a["rids"]),
                        a["input_len"], a["slice_len"]])
        elif ev["name"] == "cont":
            out.append(["cont", wid, list(a["rids"])])
    return out


# ---------------------------------------------------------------------------
# Prometheus text exposition (version 0.0.4)
# ---------------------------------------------------------------------------
def parse_prometheus(text: str) -> Dict[str, dict]:
    """Parse an exposition into ``{sample_name: {"type": ..., "help": ...,
    "samples": {labelstring: value}}}``; raises ValueError on malformed
    lines.  Deliberately strict — it guards what real scrapers ingest."""
    families: Dict[str, dict] = {}

    def family(name: str) -> dict:
        return families.setdefault(
            name, {"type": None, "help": None, "samples": {}})

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_text = rest.partition(" ")
            family(name)["help"] = help_text
        elif line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            if kind not in ("counter", "gauge", "histogram", "summary",
                            "untyped"):
                raise ValueError(f"line {lineno}: bad TYPE {kind!r}")
            family(name)["type"] = kind
        elif line.startswith("#"):
            continue  # other comments are legal
        else:
            # <name>{labels} <value>  — labels optional
            if "{" in line:
                name, _, rest = line.partition("{")
                labels, _, value = rest.rpartition("} ")
                labelstr = "{" + labels + "}"
            else:
                name, _, value = line.rpartition(" ")
                labelstr = ""
            if not name or not value:
                raise ValueError(f"line {lineno}: malformed sample "
                                 f"{line!r}")
            try:
                v = float(value)
            except ValueError:
                raise ValueError(f"line {lineno}: non-numeric value "
                                 f"{value!r}") from None
            # _bucket/_sum/_count samples belong to the histogram family
            base = name
            for suffix in ("_bucket", "_sum", "_count"):
                if name.endswith(suffix) \
                        and name[:-len(suffix)] in families:
                    base = name[:-len(suffix)]
                    break
            family(base)["samples"][name + labelstr] = v
    return families


def validate_prometheus(text: str) -> List[str]:
    """Exposition-level errors ([] = valid): parses, every sample has a
    TYPE, histogram buckets are cumulative and end at le="+Inf" == _count."""
    try:
        families = parse_prometheus(text)
    except ValueError as e:
        return [str(e)]
    errors: List[str] = []
    for name, fam in sorted(families.items()):
        if fam["type"] is None:
            errors.append(f"{name}: samples without a # TYPE line")
            continue
        if fam["type"] != "histogram":
            continue
        # per label-subset: cumulative buckets, +Inf terminal, == _count
        buckets = [(k, v) for k, v in fam["samples"].items()
                   if k.startswith(name + "_bucket")]
        series: Dict[str, List[Tuple[float, float]]] = {}
        for key, v in buckets:
            labels = key[len(name + "_bucket"):]
            le_start = labels.find('le="') + len('le="')
            le = labels[le_start:labels.find('"', le_start)]
            rest = labels.replace(f'le="{le}"', "").replace(",}", "}")
            series.setdefault(rest, []).append(
                (math.inf if le == "+Inf" else float(le), v))
        for rest, pts in sorted(series.items()):
            pts.sort()
            if not pts or not math.isinf(pts[-1][0]):
                errors.append(f"{name}{rest}: no le=\"+Inf\" bucket")
                continue
            counts = [v for _, v in pts]
            if any(b > a for a, b in zip(counts[1:], counts)):
                errors.append(f"{name}{rest}: buckets not cumulative")
            total_key = name + "_count" + ("" if rest == "{}" else rest)
            total = fam["samples"].get(total_key)
            if total is None:
                errors.append(f"{name}{rest}: missing _count")
            elif total != counts[-1]:
                errors.append(f"{name}{rest}: le=\"+Inf\" ({counts[-1]}) "
                              f"!= _count ({total})")
            if name + "_sum" + ("" if rest == "{}" else rest) \
                    not in fam["samples"]:
                errors.append(f"{name}{rest}: missing _sum")
    return errors


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON (--trace-out)")
    ap.add_argument("--metrics", help="Prometheus text file (curl /metrics)")
    ap.add_argument("--decisions", help="decision-audit dump "
                                        "(trace.decisions.json)")
    args = ap.parse_args(argv)

    errors: List[str] = []
    trace = json.loads(pathlib.Path(args.trace).read_text())
    errors += [f"{args.trace}: {e}" for e in validate_trace(trace)]
    n_slices = len(trace_slice_log(trace))
    print(f"[validate_obs] {args.trace}: "
          f"{len(trace.get('traceEvents', []))} events, "
          f"{n_slices} dispatch spans")
    if n_slices == 0:
        errors.append(f"{args.trace}: no slice/cont dispatch spans — "
                      f"the run served nothing or tracing was off")

    if args.metrics:
        text = pathlib.Path(args.metrics).read_text()
        errors += [f"{args.metrics}: {e}" for e in validate_prometheus(text)]
        fams = parse_prometheus(text)
        scls = [n for n in fams if n.startswith("scls_")]
        print(f"[validate_obs] {args.metrics}: {len(fams)} metric "
              f"families ({len(scls)} scls_*)")
        if not scls:
            errors.append(f"{args.metrics}: no scls_* metric families")

    if args.decisions:
        events = json.loads(pathlib.Path(args.decisions).read_text())
        if not isinstance(events, list):
            errors.append(f"{args.decisions}: top level must be a list")
        else:
            bad = [e for e in events
                   if not isinstance(e, dict)
                   or not {"seq", "ts", "kind"} <= set(e)]
            if bad:
                errors.append(f"{args.decisions}: {len(bad)} events "
                              f"missing seq/ts/kind")
            print(f"[validate_obs] {args.decisions}: {len(events)} "
                  f"decision events")

    for e in errors:
        print(f"[validate_obs] ERROR: {e}", file=sys.stderr)
    if not errors:
        print("[validate_obs] OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
