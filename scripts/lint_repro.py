#!/usr/bin/env python
"""Run the repro-specific static-analysis suite — the CI blocking lint.

Equivalent to ``PYTHONPATH=src python -m repro.analysis`` but runnable
from anywhere without setting PYTHONPATH:

  python scripts/lint_repro.py --all
  python scripts/lint_repro.py --rule obs-guard src/repro/serving
  python scripts/lint_repro.py --list-rules

See docs/static_analysis.md for the rule catalog and suppression syntax.
"""
from __future__ import annotations

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
