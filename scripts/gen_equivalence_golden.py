"""Regenerate tests/data/golden_batch_compositions.json.

The golden file pins the exact batch compositions (which requests are
dispatched together, where, and with what slice) produced by the
pre-`SchedulerCore` ``ClusterSimulator`` (commit 307a423) for a fixed
trace/seed under sls / ils / scls / scls-cb.  ``tests/test_serving.py::
test_scheduler_core_matches_legacy_batch_compositions`` replays the same
scenarios through the refactored core and asserts byte-identical logs, so
the sim backend can never silently drift from the legacy scheduler.

  PYTHONPATH=src python scripts/gen_equivalence_golden.py

Only rerun this when a change *intends* to alter scheduling decisions;
the diff of the JSON then documents exactly what changed.
"""
from __future__ import annotations

import copy
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster.simulator import ClusterSimulator  # noqa: E402
from repro.cluster.trace import CODEFUSE, generate_trace  # noqa: E402
from repro.core.estimator import (ServingTimeEstimator,  # noqa: E402
                                  a100_llama13b_profile)
from repro.core.memory import (A100_80GB_AVAILABLE,  # noqa: E402
                               AnalyticMemoryEstimator, LLAMA2_13B_DELTA)
from repro.core.schedulers import make_strategy  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                   "golden_batch_compositions.json")

SCENARIOS = [
    # (strategy, noise_sigma)
    ("sls", 0.0), ("ils", 0.0), ("scls", 0.0), ("scls-cb", 0.0),
    ("sls", 0.05), ("ils", 0.05), ("scls", 0.05), ("scls-cb", 0.05),
]


def build_env():
    true_lat = a100_llama13b_profile()
    rng = np.random.default_rng(0)
    pre = [(N, L, true_lat.t_prefill(N, L) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=A100_80GB_AVAILABLE, zeta=0.9)
    return true_lat, est, mem


def run_one(name: str, noise_sigma: float):
    true_lat, est, mem = build_env()
    trace = generate_trace(3.0, 60.0, CODEFUSE, seed=7)
    s = make_strategy(name, slice_len=64, fixed_batch_size=8, gamma=3.0,
                      max_parallel=8)
    sim = ClusterSimulator(s, 3, true_lat, est, mem,
                           noise_sigma=noise_sigma, seed=2)
    if not hasattr(sim, "batch_log"):  # pre-refactor legacy: instrument
        sim.batch_log = []
        orig_start, orig_cont = sim._start_batch, sim._continuous_step

        def start_batch(w):
            if not w.busy and w.queue:
                b = w.queue[0]
                sim.batch_log.append(
                    ["static", w.wid, sorted(r.rid for r in b.requests),
                     int(b.input_len), int(b.slice_len)])
            orig_start(w)

        def continuous_step(w):
            orig_cont(w)
            if w.busy and w.running:
                sim.batch_log.append(
                    ["cont", w.wid, sorted(e[0].rid for e in w.running)])

        sim._start_batch = start_batch
        sim._continuous_step = continuous_step
    res = sim.run(copy.deepcopy(trace), 60.0)
    return dict(strategy=name, noise_sigma=noise_sigma,
                n_requests=len(trace),
                n_completed=res.metrics.n_completed,
                batch_log=sim.batch_log)


def main():
    out = {"scenario_args": dict(rate=3.0, duration=60.0, workload="codefuse",
                                 trace_seed=7, workers=3, slice_len=64,
                                 fixed_batch_size=8, gamma=3.0, max_parallel=8,
                                 sim_seed=2),
           "runs": [run_one(n, sig) for n, sig in SCENARIOS]}
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(out, f, separators=(",", ":"))
        f.write("\n")
    for r in out["runs"]:
        print(f"{r['strategy']:8s} sigma={r['noise_sigma']:<5} "
              f"{len(r['batch_log'])} dispatches, "
              f"{r['n_completed']}/{r['n_requests']} completed")
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
