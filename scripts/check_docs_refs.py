#!/usr/bin/env python
"""Docs reference checker (CI docs job).

Asserts that every ``path.py:Symbol`` reference in the docs actually
resolves — the file exists AND the symbol imports — and that every local
markdown link points at an existing file.  Keeps docs/paper_map.md and
docs/architecture.md honest as the code evolves.

  PYTHONPATH=src python scripts/check_docs_refs.py [files...]

With no arguments, checks every ``*.md`` under docs/ plus README.md.
Exits non-zero listing all stale references.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# `src/repro/core/memory.py:AnalyticMemoryEstimator.kv_bytes` inside backticks
REF_RE = re.compile(r"`([\w/.-]+\.py):([A-Za-z_][\w.]*)`")
# [text](local/path.md) — skip URLs and intra-page anchors
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#]+?)(?:#[^)]*)?\)")


def module_name(path: str) -> str:
    p = pathlib.PurePosixPath(path)
    parts = p.with_suffix("").parts
    if parts[0] == "src":
        parts = parts[1:]
    return ".".join(parts)


def check_symbol_ref(path: str, symbol: str) -> str | None:
    """Returns an error string, or None when the reference resolves."""
    if not (REPO / path).is_file():
        return f"file does not exist: {path}"
    try:
        mod = importlib.import_module(module_name(path))
    except Exception as e:  # noqa: BLE001 — any import failure is a doc bug
        return f"cannot import {module_name(path)}: {e!r}"
    obj = mod
    for attr in symbol.split("."):
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{module_name(path)} has no symbol {symbol!r}"
    return None


def check_file(md: pathlib.Path) -> list[str]:
    text = md.read_text()
    errors = []
    for path, symbol in REF_RE.findall(text):
        err = check_symbol_ref(path, symbol)
        if err:
            errors.append(f"{md.relative_to(REPO)}: `{path}:{symbol}` — {err}")
    for target in LINK_RE.findall(text):
        if "://" in target or target.startswith("mailto:"):
            continue
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    errors = []
    n_refs = 0
    for md in files:
        n_refs += len(REF_RE.findall(md.read_text()))
        errors.extend(check_file(md))
    if errors:
        print(f"[check_docs_refs] {len(errors)} stale reference(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"[check_docs_refs] OK: {n_refs} symbol refs across "
          f"{len(files)} files resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
