#!/usr/bin/env python
"""Docs reference checker (CI docs job) — now a thin shim.

The actual logic lives in the ``docs-refs`` pass of the repro.analysis
suite (`src/repro/analysis/passes/docs_refs.py:DocsRefsPass`), where it
shares the findings format, per-line suppressions, and baseline support
with every other rule.  This entry point is kept for the existing CI
wiring and muscle memory:

  PYTHONPATH=src python scripts/check_docs_refs.py [files...]

With no arguments, checks every ``*.md`` under docs/ plus README.md.
Exits non-zero listing all stale references.
"""
from __future__ import annotations

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import SourceFile, run_analysis  # noqa: E402
from repro.analysis.passes.docs_refs import DocsRefsPass  # noqa: E402


def main(argv: list[str]) -> int:
    paths = [pathlib.Path(a).resolve() for a in argv] or None
    report = run_analysis(repo=REPO, rules=["docs-refs"], paths=paths)
    if not report.ok:
        print(f"[check_docs_refs] {len(report.findings)} stale reference(s):")
        for f in report.findings:
            print(f"  - {f.render(with_hint=False)}")
        return 1
    pa = DocsRefsPass()
    files = paths if paths is not None else pa.files(REPO)
    n_refs = sum(pa.count_refs(SourceFile(REPO, p)) for p in files)
    print(f"[check_docs_refs] OK: {n_refs} symbol refs across "
          f"{len(files)} files resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
