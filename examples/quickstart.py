"""Quickstart: serve a small LLaMA with slice-level scheduling (SCLS).

  PYTHONPATH=src python examples/quickstart.py

What happens (all real JAX execution on CPU):
  1. build a reduced llama3.2 and profile its prefill/decode latency;
  2. fit the paper's serving-time estimator (Eq. 3/4);
  3. a burst of requests is DP-batched (Algorithm 1), offloaded max-min to
     two workers, and served slice by slice (S = 8) with rescheduling;
  4. every request's tokens are checked against one-shot generation.
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.cluster.trace import WorkloadSpec, generate_trace
from repro.configs import get_config
from repro.engine.profiler import fit_estimator
from repro.engine.static_engine import StaticEngine
from repro.models.registry import get_model
from repro.serving import ServingConfig


def main():
    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} (reduced) — {cfg.n_layers}L d{cfg.d_model}")

    est, prmse, drmse = fit_estimator(model, params, batch_sizes=(1, 2, 4),
                                      input_lens=(16, 32))
    print(f"estimator fit: prefill rmse {prmse*1e3:.2f}ms, "
          f"decode rmse {drmse*1e3:.2f}ms")

    serve_cfg = ServingConfig(strategy="scls", backend="real", workers=2,
                              slice_len=8, max_gen=24, gamma=0.25,
                              m_available=64e6, mem_bucket=8)
    mem = serve_cfg.memory_estimator(model.kv_bytes_per_token())
    spec = WorkloadSpec("demo", input_mu=3.0, input_sigma=0.6,
                        gen_mu=2.2, gen_sigma=0.6, max_input=48, max_gen=24)
    trace = generate_trace(rate=2.0, duration=10.0, spec=spec, seed=7,
                           vocab_size=cfg.vocab_size)
    print(f"workload: {len(trace)} Poisson requests over 10s")

    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)
               for _ in range(2)]
    server = serve_cfg.build_real(engines, est, mem)
    server.replay(trace)
    metrics = server.drain(10.0)

    print(f"\nthroughput      : {metrics.throughput:.2f} req/s (virtual time)")
    print(f"mean response   : {metrics.mean_response:.2f} s")
    print(f"TTFT mean       : {metrics.ttft_mean:.2f} s")
    print(f"avg batch size  : {metrics.avg_batch_size:.1f}")
    print(f"avg slices/req  : {metrics.avg_schedules:.2f}")
    print(f"worker CT std   : {metrics.ct_std:.2f} s")

    # verify slice-level serving produced exactly the one-shot tokens
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    ok = 0
    for r in trace[:8]:
        want = eng.serve_batch([r.prompt], slice_len=32,
                               forced_gen_lens=[min(r.gen_len, r.max_gen)]
                               ).results[0]["tokens"]
        ok += (r.output_tokens == want)
    print(f"token parity with one-shot generation: {ok}/8 OK")


if __name__ == "__main__":
    main()
