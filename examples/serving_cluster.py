"""Paper-scale cluster comparison: SLS vs ILS vs SCLS (+ ablations) on
8 simulated A100/LLaMA2-13B workers — reproduces the shape of Fig. 12/15/17,
now driven through the online ``repro.serving`` API: every strategy runs a
``SliceServer`` (submit → slice scheduling → drain) over the shared
``SchedulerCore`` with the sim backend.

  PYTHONPATH=src python examples/serving_cluster.py [--rate 20] [--duration 300]
"""
import argparse
import copy
import sys

sys.path.insert(0, "src")

from repro.core.memory import RuleBasedMemoryEstimator
from repro.core.schedulers import ALL_STRATEGIES
from repro.serving import ServingConfig, default_sim_environment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=300.0)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--slice-len", type=int, default=128)
    args = ap.parse_args()

    from repro.cluster.trace import CODEFUSE, generate_trace

    # paper testbed wiring, centralized in repro.serving (DS profile:
    # Algorithm 2 rule table for memory)
    true_lat, est, _ = default_sim_environment("ds")
    trace = generate_trace(args.rate, args.duration, CODEFUSE, seed=1)
    print(f"{len(trace)} requests @ {args.rate}/s over {args.duration:.0f}s, "
          f"{args.workers} workers (DS profile)\n")
    hdr = f"{'strategy':8s} {'thr(req/s)':>10s} {'resp(s)':>9s} {'p95(s)':>8s} " \
          f"{'p99(s)':>8s} {'ttft(s)':>8s} {'CTstd(s)':>9s} {'batch':>6s} " \
          f"{'invalid':>8s} {'pads':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for name in ALL_STRATEGIES:
        cfg = ServingConfig(strategy=name, backend="sim",
                            workers=args.workers, slice_len=args.slice_len,
                            fixed_batch_size=12, gamma=3.0, max_parallel=12,
                            noise_sigma=0.02, seed=2)
        server = cfg.build_sim(true_lat, est, RuleBasedMemoryEstimator())
        server.replay(copy.deepcopy(trace))
        m = server.drain(args.duration)
        assert m.n_completed > 0, f"{name}: no requests completed"
        print(f"{m.name:8s} {m.throughput:10.2f} {m.mean_response:9.1f} "
              f"{m.p95_response:8.1f} {m.p99_response:8.1f} "
              f"{m.ttft_mean:8.1f} {m.ct_std:9.1f} {m.avg_batch_size:6.1f} "
              f"{m.avg_invalid_tokens:8.1f} {m.avg_pad_tokens:7.1f}")


if __name__ == "__main__":
    main()
