"""Paper-scale cluster comparison: SLS vs ILS vs SCLS (+ ablations) on
8 simulated A100/LLaMA2-13B workers — reproduces the shape of Fig. 12/15/17.

  PYTHONPATH=src python examples/serving_cluster.py [--rate 20] [--duration 300]
"""
import argparse
import copy
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import CODEFUSE, generate_trace
from repro.core.estimator import ServingTimeEstimator, a100_llama13b_profile
from repro.core.memory import RuleBasedMemoryEstimator
from repro.core.schedulers import ALL_STRATEGIES, make_strategy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=300.0)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--slice-len", type=int, default=128)
    args = ap.parse_args()

    true_lat = a100_llama13b_profile()
    rng = np.random.default_rng(0)
    pre = [(N, L, true_lat.t_prefill(N, L) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    mem = RuleBasedMemoryEstimator()  # paper Algorithm 2 (DS engine)
    trace = generate_trace(args.rate, args.duration, CODEFUSE, seed=1)
    print(f"{len(trace)} requests @ {args.rate}/s over {args.duration:.0f}s, "
          f"{args.workers} workers (DS profile)\n")
    hdr = f"{'strategy':8s} {'thr(req/s)':>10s} {'resp(s)':>9s} {'p95(s)':>8s} " \
          f"{'CTstd(s)':>9s} {'batch':>6s} {'invalid':>8s} {'pads':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for name in ALL_STRATEGIES:
        s = make_strategy(name, slice_len=args.slice_len, fixed_batch_size=12,
                          gamma=3.0, max_parallel=12)
        sim = ClusterSimulator(s, args.workers, true_lat, est, mem,
                               noise_sigma=0.02, seed=2)
        m = sim.run(copy.deepcopy(trace), args.duration).metrics
        print(f"{m.name:8s} {m.throughput:10.2f} {m.mean_response:9.1f} "
              f"{m.p95_response:8.1f} {m.ct_std:9.1f} {m.avg_batch_size:6.1f} "
              f"{m.avg_invalid_tokens:8.1f} {m.avg_pad_tokens:7.1f}")


if __name__ == "__main__":
    main()
