"""Paper-scale cluster comparison: SLS vs ILS vs SCLS (+ ablations) on
8 simulated A100/LLaMA2-13B workers — reproduces the shape of Fig. 12/15/17,
now driven through the online ``repro.serving`` API: every strategy runs a
``SliceServer`` (submit → slice scheduling → drain) over the shared
``SchedulerCore`` with the sim backend.

A second section then exercises the *concurrent* front end
(``AsyncSliceServer``): a gather of asyncio clients with mixed per-request
SLOs, one of which cancels mid-stream — submit / per-slice streaming /
SLO-aware admission / cancellation end to end on one scheduler.

  PYTHONPATH=src python examples/serving_cluster.py [--rate 20] [--duration 300]
"""
import argparse
import asyncio
import copy
import sys

sys.path.insert(0, "src")

from repro.core.memory import RuleBasedMemoryEstimator
from repro.core.schedulers import ALL_STRATEGIES
from repro.serving import (AdmissionRejected, ServingConfig,
                           default_sim_environment)


async def concurrent_clients_demo() -> None:
    """N asyncio clients over one AsyncSliceServer, mixed SLOs, one
    mid-stream cancel."""
    server = ServingConfig(strategy="scls", workers=2, slice_len=64,
                           gamma=1.0).build_sim().aio
    # mixed traffic: generous SLOs, one unmeetable (shed at submit),
    # one best-effort (no SLO), one cancelled after its first slice
    jobs = [dict(input_len=96, gen_len=200, slo_ms=60_000),
            dict(input_len=64, gen_len=150, slo_ms=60_000),
            dict(input_len=48, gen_len=120, slo_ms=None),
            dict(input_len=900, gen_len=1000, slo_ms=200),   # doomed
            dict(input_len=80, gen_len=400, slo_ms=90_000)]  # cancels

    async def client(i: int, job: dict) -> str:
        try:
            h = server.submit(input_len=job["input_len"],
                              gen_len=job["gen_len"], slo_ms=job["slo_ms"])
        except AdmissionRejected as e:
            return f"client {i}: REJECTED at submit ({e.decision.reason})"
        n_stream = 0
        async for _tok in h.tokens():
            n_stream += 1
            if i == 4 and n_stream >= 64:  # one slice in: hang up
                h.cancel()
                break
        await h.result()
        state = "cancelled" if h.cancelled else "done"
        return (f"client {i}: {state} after {h.request.generated} tokens "
                f"({h.request.n_schedules} slices, streamed {n_stream})")

    results = await asyncio.gather(*(client(i, j) for i, j in enumerate(jobs)))
    for line in results:
        print(f"  {line}")
    m = await server.close()
    stats = server.admission_stats
    print(f"  -> {m.n_completed} completed, {stats['n_rejected']} rejected, "
          f"SLO attainment {m.slo_attainment:.2f}")
    assert m.n_completed == 3 and stats["n_rejected"] == 1
    assert any("cancelled" in line for line in results)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=300.0)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--slice-len", type=int, default=128)
    args = ap.parse_args()

    from repro.cluster.trace import CODEFUSE, generate_trace

    # paper testbed wiring, centralized in repro.serving (DS profile:
    # Algorithm 2 rule table for memory)
    true_lat, est, _ = default_sim_environment("ds")
    trace = generate_trace(args.rate, args.duration, CODEFUSE, seed=1)
    print(f"{len(trace)} requests @ {args.rate}/s over {args.duration:.0f}s, "
          f"{args.workers} workers (DS profile)\n")
    hdr = f"{'strategy':8s} {'thr(req/s)':>10s} {'resp(s)':>9s} {'p95(s)':>8s} " \
          f"{'p99(s)':>8s} {'ttft(s)':>8s} {'CTstd(s)':>9s} {'batch':>6s} " \
          f"{'invalid':>8s} {'pads':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for name in ALL_STRATEGIES:
        cfg = ServingConfig(strategy=name, backend="sim",
                            workers=args.workers, slice_len=args.slice_len,
                            fixed_batch_size=12, gamma=3.0, max_parallel=12,
                            noise_sigma=0.02, seed=2)
        server = cfg.build_sim(true_lat, est, RuleBasedMemoryEstimator())
        server.replay(copy.deepcopy(trace))
        m = server.drain(args.duration)
        assert m.n_completed > 0, f"{name}: no requests completed"
        print(f"{m.name:8s} {m.throughput:10.2f} {m.mean_response:9.1f} "
              f"{m.p95_response:8.1f} {m.p99_response:8.1f} "
              f"{m.ttft_mean:8.1f} {m.ct_std:9.1f} {m.avg_batch_size:6.1f} "
              f"{m.avg_invalid_tokens:8.1f} {m.avg_pad_tokens:7.1f}")

    print("\nconcurrent asyncio clients (AsyncSliceServer, mixed SLOs, "
          "one mid-stream cancel):")
    asyncio.run(concurrent_clients_demo())


if __name__ == "__main__":
    main()
