"""Paper-scale cluster comparison: SLS vs ILS vs SCLS (+ ablations) on
8 simulated A100/LLaMA2-13B workers — reproduces the shape of Fig. 12/15/17,
now driven through the online ``repro.serving`` API: every strategy runs a
``SliceServer`` (submit → slice scheduling → drain) over the shared
``SchedulerCore`` with the sim backend.

A second section then exercises the *concurrent* front end
(``AsyncSliceServer``): a gather of asyncio clients with mixed per-request
SLOs, one of which cancels mid-stream — submit / per-slice streaming /
SLO-aware admission / cancellation end to end on one scheduler.

A third section runs the REAL backend (reduced model, every FLOP real)
with ``--kv-retain request``: prefix KV pages persist in the engine
across slices, so resumed slices re-prefill nothing — asserted via
``reprefill_tokens == 0`` for uninterrupted requests (the paper's §3.3
overhead, eliminated).

  PYTHONPATH=src python examples/serving_cluster.py [--rate 20] [--duration 300]
"""
import argparse
import asyncio
import copy
import sys

sys.path.insert(0, "src")

from repro.core.memory import RuleBasedMemoryEstimator
from repro.core.schedulers import ALL_STRATEGIES
from repro.serving import (AdmissionRejected, ServingConfig,
                           default_sim_environment)


async def concurrent_clients_demo() -> None:
    """N asyncio clients over one AsyncSliceServer, mixed SLOs, one
    mid-stream cancel."""
    server = ServingConfig(strategy="scls", workers=2, slice_len=64,
                           gamma=1.0).build_sim().aio
    # mixed traffic: generous SLOs, one unmeetable (shed at submit),
    # one best-effort (no SLO), one cancelled after its first slice
    jobs = [dict(input_len=96, gen_len=200, slo_ms=60_000),
            dict(input_len=64, gen_len=150, slo_ms=60_000),
            dict(input_len=48, gen_len=120, slo_ms=None),
            dict(input_len=900, gen_len=1000, slo_ms=200),   # doomed
            dict(input_len=80, gen_len=400, slo_ms=90_000)]  # cancels

    async def client(i: int, job: dict) -> str:
        try:
            h = server.submit(input_len=job["input_len"],
                              gen_len=job["gen_len"], slo_ms=job["slo_ms"])
        except AdmissionRejected as e:
            return f"client {i}: REJECTED at submit ({e.decision.reason})"
        n_stream = 0
        async for _tok in h.tokens():
            n_stream += 1
            if i == 4 and n_stream >= 64:  # one slice in: hang up
                h.cancel()
                break
        await h.result()
        state = "cancelled" if h.cancelled else "done"
        return (f"client {i}: {state} after {h.request.generated} tokens "
                f"({h.request.n_schedules} slices, streamed {n_stream})")

    results = await asyncio.gather(*(client(i, j) for i, j in enumerate(jobs)))
    for line in results:
        print(f"  {line}")
    m = await server.close()
    stats = server.admission_stats
    print(f"  -> {m.n_completed} completed, {stats['n_rejected']} rejected, "
          f"SLO attainment {m.slo_attainment:.2f}")
    assert m.n_completed == 3 and stats["n_rejected"] == 1
    assert any("cancelled" in line for line in results)


def real_retain_demo() -> None:
    """Real engines, kv_retain="request": zero re-prefill on resume."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.engine.profiler import fit_estimator
    from repro.engine.static_engine import StaticEngine
    from repro.models.registry import get_model

    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 32), n_decode_iters=2,
                              repeats=1)
    page_tokens = 16
    cfg = ServingConfig(strategy="scls", backend="real", kv_layout="paged",
                        kv_retain="request", page_tokens=page_tokens,
                        slice_len=4, max_gen=16, gamma=0.25,
                        m_available=64e6, mem_bucket=8, workers=1)
    mem = cfg.memory_estimator(model.kv_bytes_per_token())
    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8,
                            kv_layout="paged", page_tokens=page_tokens,
                            kv_pool_tokens=mem.total_blocks * page_tokens)]
    server = cfg.build_real(engines, est, mem)
    rng = np.random.default_rng(7)
    handles = [server.submit(
        rng.integers(0, arch.vocab_size, size=8 + 3 * i).astype(np.int32),
        gen_len=10 + i, max_gen=16, arrival=0.1 * i) for i in range(3)]
    m = server.drain()
    slices = [h.request.n_schedules for h in handles]
    print(f"  {m.n_completed} requests in {slices} slices each, "
          f"reprefill_tokens={m.reprefill_tokens} "
          f"(retained prefix pages made every resume a page-table remap)")
    assert m.n_completed == 3 and all(h.done for h in handles)
    assert max(slices) >= 3, "multi-slice regime expected"
    # THE §3.3 claim: uninterrupted requests never re-prefill
    assert m.reprefill_tokens == 0
    # and every retained page went back to the pool on completion
    alloc = engines[0].allocator
    assert alloc.free_blocks == alloc.n_pages


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=300.0)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--slice-len", type=int, default=128)
    args = ap.parse_args()

    from repro.cluster.trace import CODEFUSE, generate_trace

    # paper testbed wiring, centralized in repro.serving (DS profile:
    # Algorithm 2 rule table for memory)
    true_lat, est, _ = default_sim_environment("ds")
    trace = generate_trace(args.rate, args.duration, CODEFUSE, seed=1)
    print(f"{len(trace)} requests @ {args.rate}/s over {args.duration:.0f}s, "
          f"{args.workers} workers (DS profile)\n")
    hdr = f"{'strategy':8s} {'thr(req/s)':>10s} {'resp(s)':>9s} {'p95(s)':>8s} " \
          f"{'p99(s)':>8s} {'ttft(s)':>8s} {'CTstd(s)':>9s} {'batch':>6s} " \
          f"{'invalid':>8s} {'pads':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for name in ALL_STRATEGIES:
        cfg = ServingConfig(strategy=name, backend="sim",
                            workers=args.workers, slice_len=args.slice_len,
                            fixed_batch_size=12, gamma=3.0, max_parallel=12,
                            noise_sigma=0.02, seed=2)
        server = cfg.build_sim(true_lat, est, RuleBasedMemoryEstimator())
        server.replay(copy.deepcopy(trace))
        m = server.drain(args.duration)
        assert m.n_completed > 0, f"{name}: no requests completed"
        print(f"{m.name:8s} {m.throughput:10.2f} {m.mean_response:9.1f} "
              f"{m.p95_response:8.1f} {m.p99_response:8.1f} "
              f"{m.ttft_mean:8.1f} {m.ct_std:9.1f} {m.avg_batch_size:6.1f} "
              f"{m.avg_invalid_tokens:8.1f} {m.avg_pad_tokens:7.1f}")

    print("\nconcurrent asyncio clients (AsyncSliceServer, mixed SLOs, "
          "one mid-stream cancel):")
    asyncio.run(concurrent_clients_demo())

    print("\nreal backend with persistent paged KV (--kv-retain request):")
    real_retain_demo()


if __name__ == "__main__":
    main()
