"""Slice-length trade-off (paper §5.5, Figs. 18-21): sweep S and watch the
throughput curve rise then fall as reschedule overhead trades against
batch size and request waiting.

Also sweeps SCLS-PRED (repro.predict) at each S: calibrated length caps
interact with the slice length as a *ceiling* — a request predicted to
finish within S is served an exact shorter slice (fewer invalid tokens,
tighter KV packing), while one predicted to outlive S falls back to plain
SCLS slicing.  Prediction therefore flattens the right side of the curve:
at over-large S the caps keep serving rounds short (at S=1024, i.e. no
slicing at all, SCLS-PRED holds ~2x the throughput of length-blind SLS
behaviour), while at small S the caps floor out and SCLS-PRED degrades
to exactly SCLS — making throughput far less sensitive to mis-tuned S.

  PYTHONPATH=src python examples/slice_length_sweep.py
"""
import copy
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.cluster.trace import CODEFUSE, generate_trace
from repro.core.memory import RuleBasedMemoryEstimator
from repro.serving import ServingConfig, default_sim_environment


def main():
    true_lat, est, _ = default_sim_environment("ds")
    trace = generate_trace(20.0, 300.0, CODEFUSE, seed=1)
    for strat in ("scls", "scls-pred"):
        print(f"--- {strat} ---")
        print(f"{'S':>5s} {'thr':>7s} {'resp(s)':>8s} {'slices':>7s} "
              f"{'batch':>6s} {'pads':>7s} {'early%':>7s} {'CTstd':>6s}")
        for S in (16, 32, 64, 128, 256, 512, 1024):
            cfg = ServingConfig(strategy=strat, workers=8, slice_len=S,
                                fixed_batch_size=12, gamma=3.0,
                                noise_sigma=0.02, seed=2)
            server = cfg.build_sim(true_lat, est, RuleBasedMemoryEstimator())
            reqs = copy.deepcopy(trace)
            server.replay(reqs)
            m = server.drain(300.0)
            sched = np.mean([r.n_schedules for r in reqs if r.done])
            print(f"{S:5d} {m.throughput:7.2f} {m.mean_response:8.1f} "
                  f"{sched:7.2f} {m.avg_batch_size:6.1f} "
                  f"{m.avg_pad_tokens:7.1f} {100*m.early_return_ratio:7.2f} "
                  f"{m.ct_std:6.1f}")


if __name__ == "__main__":
    main()
