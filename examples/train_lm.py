"""Train a reduced-config LM end to end (substrate check: data pipeline ->
model -> AdamW -> checkpoint), for any assigned architecture.

  PYTHONPATH=src python examples/train_lm.py [--arch mamba2-130m] [--steps 150]
"""
import argparse
import subprocess
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    # the launcher is the real entry point; this example just drives it
    from repro.launch import train
    sys.argv = ["train", "--arch", args.arch, "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--ckpt", "/tmp/repro_ckpt"]
    train.main()


if __name__ == "__main__":
    main()
