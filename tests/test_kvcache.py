"""Paged KV-cache subsystem (repro.kvcache): allocator invariants, page
bookkeeping, and the block-pool memory estimator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.memory import (MAX_BATCH_SIZE_CAP, AnalyticMemoryEstimator,
                               PagedMemoryEstimator, RuleBasedMemoryEstimator)
from repro.kvcache import (PageAllocator, blocks_for, clear_row,
                           init_paged_kv_cache, write_prefill_pages)
from repro.kvcache.paged import gather_row


# ---------------------------------------------------------------------------
# PageAllocator
# ---------------------------------------------------------------------------
def test_allocator_reserve_release_roundtrip():
    a = PageAllocator(n_pages=8, page_tokens=16)
    assert a.free_blocks == 8
    pages = a.reserve(owner=1, n_tokens=40)  # ceil(40/16) = 3 blocks
    assert len(pages) == 3 and a.free_blocks == 5 and a.used_blocks == 3
    assert all(p != PageAllocator.NULL_PAGE for p in pages)
    assert a.pages_of(1) == pages
    assert a.release(1) == 3
    assert a.free_blocks == 8 and a.owners() == []


def test_allocator_envelope_is_block_rounded():
    a = PageAllocator(n_pages=4, page_tokens=16)
    assert a.blocks_for_tokens(1) == 1
    assert a.blocks_for_tokens(16) == 1
    assert a.blocks_for_tokens(17) == 2
    assert blocks_for(33, 16) == 3


def test_allocator_all_or_nothing():
    a = PageAllocator(n_pages=4, page_tokens=8)
    a.reserve(owner=0, n_tokens=24)  # 3 blocks
    assert not a.can_reserve(16)     # 2 blocks > 1 free
    with pytest.raises(MemoryError):
        a.reserve(owner=1, n_tokens=16)
    assert a.free_blocks == 1        # failed reserve took nothing


def test_allocator_rejects_double_reserve_and_unknown_release():
    a = PageAllocator(n_pages=4, page_tokens=8)
    a.reserve(owner=7, n_tokens=8)
    with pytest.raises(KeyError):
        a.reserve(owner=7, n_tokens=8)
    with pytest.raises(KeyError):
        a.release(99)


def test_allocator_pages_are_exclusive():
    a = PageAllocator(n_pages=6, page_tokens=8)
    p1 = a.reserve(owner=1, n_tokens=20)
    p2 = a.reserve(owner=2, n_tokens=20)
    assert not set(p1) & set(p2)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 100), min_size=1, max_size=20),
       st.sampled_from([4, 8, 16]))
def test_allocator_never_oversubscribes(token_requests, page_tokens):
    """Property: pages handed out never exceed the pool, every page id is
    unique and non-null, and releasing everything restores the free list."""
    a = PageAllocator(n_pages=10, page_tokens=page_tokens)
    live = {}
    for owner, toks in enumerate(token_requests):
        if a.can_reserve(toks):
            live[owner] = a.reserve(owner, toks)
    handed = [p for pages in live.values() for p in pages]
    assert len(handed) == len(set(handed)) <= 10
    assert PageAllocator.NULL_PAGE not in handed
    assert a.used_blocks == len(handed)
    for owner in list(live):
        a.release(owner)
    assert a.free_blocks == 10


# ---------------------------------------------------------------------------
# PagedKVCache bookkeeping
# ---------------------------------------------------------------------------
def test_write_prefill_pages_then_gather_roundtrip():
    L, pg, Hkv, D = 2, 4, 2, 8
    cache = init_paged_kv_cache(L, batch=2, n_pages=6, page_tokens=pg,
                                max_blocks_per_row=3, n_kv=Hkv, head_dim=D,
                                dtype=jnp.float32)
    assert cache.window == 12 and cache.n_pages == 7  # +1 null page
    k = jax.random.normal(jax.random.PRNGKey(0), (L, 6, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(1), (L, 6, Hkv, D))
    sp = np.array([-1, 0, 1, 2, 3, 4], np.int32)  # left-padded positions
    cache = write_prefill_pages(cache, row=0, page_ids=[3, 5], k=k, v=v,
                                prefill_slot_pos=sp, length=5)
    np.testing.assert_array_equal(np.asarray(cache.block_table[0]), [3, 5, 0])
    np.testing.assert_array_equal(np.asarray(cache.slot_pos[0, :6]), sp)
    assert (np.asarray(cache.slot_pos[0, 6:]) == -1).all()
    gk, gv = gather_row(cache, 0)
    # logical blocks 0,1 live in pages 3,5: prefill slots + zero pad
    np.testing.assert_allclose(gk[:, :8], np.asarray(jnp.pad(
        k, ((0, 0), (0, 2), (0, 0), (0, 0)))))
    np.testing.assert_allclose(gv[:, :8], np.asarray(jnp.pad(
        v, ((0, 0), (0, 2), (0, 0), (0, 0)))))
    assert (gk[:, 8:] == 0).all()  # unused block -> null page
    assert int(cache.lengths[0]) == 5


def test_write_prefill_pages_overflow_raises():
    cache = init_paged_kv_cache(1, batch=1, n_pages=4, page_tokens=4,
                                max_blocks_per_row=2, n_kv=1, head_dim=4,
                                dtype=jnp.float32)
    k = jnp.zeros((1, 5, 1, 4))
    with pytest.raises(ValueError):
        write_prefill_pages(cache, 0, [1], k, k, np.arange(5), 5)


def test_clear_row_unmaps_and_masks():
    cache = init_paged_kv_cache(1, batch=2, n_pages=4, page_tokens=4,
                                max_blocks_per_row=2, n_kv=1, head_dim=4,
                                dtype=jnp.float32)
    k = jnp.ones((1, 4, 1, 4))
    cache = write_prefill_pages(cache, 1, [2], k, k, np.arange(4), 4)
    cache = clear_row(cache, 1)
    assert (np.asarray(cache.block_table[1]) == 0).all()
    assert (np.asarray(cache.slot_pos[1]) == -1).all()


# ---------------------------------------------------------------------------
# PagedMemoryEstimator (block pool view of Eq. 5/9)
# ---------------------------------------------------------------------------
def test_paged_estimator_counts_blocks():
    # 64 tokens of budget in 16-token blocks = 4 blocks
    mem = PagedMemoryEstimator(delta_bytes=1.0, m_available=64.0,
                               page_tokens=16)
    assert mem.total_blocks == 4
    assert mem.blocks_per_request(20, 10) == 2  # ceil(30/16)
    assert mem.fits(2, 20, 10) and not mem.fits(3, 20, 10)
    assert mem.max_batch_size(20, 10) == 2


def test_paged_estimator_tracks_inflight_reservations():
    mem = PagedMemoryEstimator(delta_bytes=1.0, m_available=128.0,
                               page_tokens=16)  # 8 blocks
    assert mem.max_batch_size(16, 16) == 4      # 2 blocks each
    held = mem.reserve_batch(2, 16, 16)         # 4 blocks in flight
    assert mem.free_blocks == 4
    assert mem.max_batch_size(16, 16) == 2      # counts FREE blocks
    assert not mem.fits(3, 16, 16)
    mem.release_blocks(held)
    assert mem.max_batch_size(16, 16) == 4


def test_paged_estimator_rounding_never_beats_analytic():
    """Block rounding can only cost capacity vs. the idealized closed form."""
    an = AnalyticMemoryEstimator(delta_bytes=100.0, m_available=1e6)
    pg = PagedMemoryEstimator(delta_bytes=100.0, m_available=1e6,
                              page_tokens=16)
    for L, S in [(10, 28), (100, 128), (1000, 128)]:
        assert pg.max_batch_size(L, S) <= an.max_batch_size(L, S)


# ---------------------------------------------------------------------------
# max_batch_size sentinel regression (satellite): the old code returned the
# raw 1 << 20 doubling sentinel when the memory model never binds
# ---------------------------------------------------------------------------
def test_max_batch_size_cap_never_leaks_sentinel():
    unbounded = [
        AnalyticMemoryEstimator(delta_bytes=0.0, m_available=1e9),
        PagedMemoryEstimator(delta_bytes=0.0, m_available=1e9),
        RuleBasedMemoryEstimator(rules=((0, 1 << 30),)),  # always fits
    ]
    for mem in unbounded:
        n = mem.max_batch_size(100, 128)
        assert n == MAX_BATCH_SIZE_CAP, mem
        assert n < 1 << 20  # the documented cap, not the search sentinel


def test_max_batch_size_cap_does_not_change_bounded_answers():
    mem = AnalyticMemoryEstimator(delta_bytes=1000.0, m_available=1e6)
    for L in (10, 100, 500):
        n = mem.max_batch_size(L, 28)
        assert mem.fits(n, L, 28) and not mem.fits(n + 1, L, 28)
    rule = RuleBasedMemoryEstimator()  # generic bisection path
    assert rule.max_batch_size(1000, 128) == 12
    assert rule.max_batch_size(100, 128) == 28


def test_allocator_double_release_raises_without_corruption():
    """Satellite regression: releasing an owner that holds nothing raises
    a descriptive error instead of silently corrupting the free list, and
    ``missing_ok=True`` is the explicit idempotent escape hatch."""
    a = PageAllocator(n_pages=4, page_tokens=8)
    a.reserve(owner=1, n_tokens=16)
    assert a.release(1) == 2
    with pytest.raises(KeyError, match="double release"):
        a.release(1)
    assert a.free_blocks == 4          # the failed release took nothing
    assert a.release(1, missing_ok=True) == 0
    assert a.free_blocks == 4


def test_allocator_cancel_then_slice_end_path():
    """The serving cancel path: cancellation itself must not release the
    slice envelope (slice end releases exactly once); a buggy duplicate
    release raises, and afterwards every page is still handed out exactly
    once."""
    a = PageAllocator(n_pages=4, page_tokens=8)
    a.reserve(owner=7, n_tokens=16)    # slice start: envelope reserved
    assert a.release(7) == 2           # slice end (cancelled or not)
    with pytest.raises(KeyError):      # cancel must NOT also release
        a.release(7)
    pages = a.reserve(owner=8, n_tokens=32)
    assert sorted(pages) == [1, 2, 3, 4]  # free list intact, no duplicates


# ---------------------------------------------------------------------------
# extend / shrink (persistent retention, PR 5)
# ---------------------------------------------------------------------------
def test_allocator_extend_grows_in_place():
    a = PageAllocator(n_pages=8, page_tokens=8)
    first = a.reserve(owner=1, n_tokens=16)       # 2 pages
    assert a.extend(owner=1, n_tokens=16) == []   # already covered
    new = a.extend(owner=1, n_tokens=40)          # grow to 5 pages
    assert len(new) == 3 and a.pages_of(1) == first + new
    assert a.free_blocks == 3
    with pytest.raises(KeyError):
        a.extend(owner=2, n_tokens=8)             # unknown owner
    with pytest.raises(MemoryError):
        a.extend(owner=1, n_tokens=100)           # 13 > 8 pages
    assert a.pages_of(1) == first + new           # failed extend took nothing


def test_allocator_shrink_frees_tail_keeps_prefix():
    a = PageAllocator(n_pages=8, page_tokens=8)
    pages = a.reserve(owner=1, n_tokens=48)       # 6 pages
    assert a.shrink(owner=1, n_tokens=20) == 3    # keep ceil(20/8) = 3
    assert a.pages_of(1) == pages[:3]             # prefix mapping untouched
    assert a.free_blocks == 5
    assert a.shrink(owner=1, n_tokens=24) == 0    # nothing to trim
    with pytest.raises(KeyError):
        a.shrink(owner=9, n_tokens=8)
    assert a.release(1) == 3
    assert a.free_blocks == 8


def test_append_prefill_compact_layout_roundtrip():
    """append_prefill writes tokens at slot == position and extends the
    retained prefix without touching it — the host-side twin of the
    batched prefill_paged path."""
    from repro.kvcache import append_prefill
    L, pg, Hkv, D = 2, 4, 1, 8
    cache = init_paged_kv_cache(L, batch=1, n_pages=4, page_tokens=pg,
                                max_blocks_per_row=3, n_kv=Hkv, head_dim=D,
                                dtype=jnp.float32)
    k1 = jax.random.normal(jax.random.PRNGKey(0), (L, 5, Hkv, D))
    cache = append_prefill(cache, row=0, page_ids=[2, 3], k=k1, v=k1,
                           start=0, n_new=5)
    np.testing.assert_array_equal(np.asarray(cache.block_table[0]), [2, 3, 0])
    np.testing.assert_array_equal(np.asarray(cache.slot_pos[0, :5]),
                                  np.arange(5))
    k2 = jax.random.normal(jax.random.PRNGKey(1), (L, 3, Hkv, D))
    cache = append_prefill(cache, row=0, page_ids=[2, 3], k=k2, v=k2,
                           start=5, n_new=3)
    gk, _ = gather_row(cache, 0)
    np.testing.assert_allclose(gk[:, :5], np.asarray(k1))   # prefix intact
    np.testing.assert_allclose(gk[:, 5:8], np.asarray(k2))  # appended
    assert int(cache.lengths[0]) == 8
    with pytest.raises(ValueError):
        append_prefill(cache, 0, [2], k1, k1, start=0, n_new=5)  # overflow


def test_batch_views_remap_retained_rows():
    from repro.kvcache import batch_block_table, batch_slot_pos
    bt = batch_block_table([[3, 1], [2], []], n_blocks=3)
    np.testing.assert_array_equal(bt, [[3, 1, 0], [2, 0, 0], [0, 0, 0]])
    with pytest.raises(ValueError):
        batch_block_table([[1, 2, 3, 4]], n_blocks=3)
    sp = batch_slot_pos([5, 0], n_blocks=2, page_tokens=4)
    np.testing.assert_array_equal(sp[0], [0, 1, 2, 3, 4, -1, -1, -1])
    assert (sp[1] == -1).all()


# ---------------------------------------------------------------------------
# refcounted sharing + copy-on-write (PR 7)
# ---------------------------------------------------------------------------
def test_share_takes_references_and_frees_on_last_release():
    a = PageAllocator(n_pages=8, page_tokens=8)
    donor = a.reserve(owner=1, n_tokens=32)           # 4 pages
    shared = a.share(owner=2, pages=donor[:2])
    assert shared == donor[:2]
    assert a.shared_blocks == 2
    assert [a.ref_count(p) for p in donor] == [2, 2, 1, 1]
    assert a.used_blocks == 4                          # no new allocation
    # donor releases first: shared pages stay live for owner 2
    a.release(1)
    assert [a.ref_count(p) for p in donor] == [1, 1, 0, 0]
    assert a.used_blocks == 2 and a.shared_blocks == 0
    a.release(2)
    assert a.free_blocks == 8 and a.owners() == []


def test_share_rejects_existing_owner_free_page_and_null():
    a = PageAllocator(n_pages=4, page_tokens=8)
    pages = a.reserve(owner=1, n_tokens=8)
    with pytest.raises(KeyError):
        a.share(owner=1, pages=pages)                  # owner already holds
    free_page = a.n_pages                              # still on the free list
    with pytest.raises(ValueError):
        a.share(owner=2, pages=[free_page])
    with pytest.raises(ValueError):
        a.share(owner=2, pages=[PageAllocator.NULL_PAGE])
    assert a.owners() == [1]                           # nothing leaked


def test_fork_is_noop_on_exclusive_and_copies_on_shared():
    a = PageAllocator(n_pages=8, page_tokens=8)
    donor = a.reserve(owner=1, n_tokens=16)            # 2 pages
    old, new = a.fork(owner=1, index=0)                # exclusive: no-op
    assert old == new == donor[0]
    a.share(owner=2, pages=donor)
    old, new = a.fork(owner=2, index=1)                # shared: private copy
    assert old == donor[1] and new != old
    assert a.ref_count(old) == 1 and a.ref_count(new) == 1
    assert a.pages_of(1) == donor                      # donor mapping intact
    assert a.pages_of(2) == [donor[0], new]
    assert a.shared_blocks == 1                        # only page 0 still shared


def test_fork_raises_when_pool_dry_without_corruption():
    a = PageAllocator(n_pages=2, page_tokens=8)
    donor = a.reserve(owner=1, n_tokens=16)            # whole pool
    a.share(owner=2, pages=donor)
    with pytest.raises(MemoryError):
        a.fork(owner=2, index=0)
    assert a.pages_of(2) == donor                      # entry not swapped
    assert a.ref_count(donor[0]) == 2                  # refcount untouched


def test_shrink_on_shared_tail_drops_ref_not_page():
    a = PageAllocator(n_pages=4, page_tokens=8)
    donor = a.reserve(owner=1, n_tokens=24)            # 3 pages
    a.share(owner=2, pages=donor)
    a.shrink(2, 8)                                     # owner 2 keeps 1 page
    assert a.pages_of(2) == donor[:1]
    assert a.pages_of(1) == donor                      # donor untouched
    assert [a.ref_count(p) for p in donor] == [2, 1, 1]
    assert a.free_blocks == 1                          # nothing freed yet


def _churn_check(a, mirror):
    """The conservation + refcount invariants after every churn op."""
    from collections import Counter
    counts = Counter(p for pages in mirror.values() for p in pages)
    assert a.used_blocks + a.free_blocks == a.n_pages
    assert a.used_blocks == len(counts)
    for p, c in counts.items():
        assert a.ref_count(p) == c
    assert a.shared_blocks == sum(1 for c in counts.values() if c > 1)
    free = set(a._free)
    assert len(free) == len(a._free)                   # no double-free
    assert free.isdisjoint(counts)                     # live pages never free
    assert PageAllocator.NULL_PAGE not in counts
    assert PageAllocator.NULL_PAGE not in free
    for o, pages in mirror.items():
        assert a.pages_of(o) == pages


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(
           st.sampled_from(["reserve", "extend", "shrink", "share",
                            "fork", "release"]),
           st.integers(0, 5), st.integers(1, 80)),
       min_size=1, max_size=60),
       st.sampled_from([4, 8, 16]))
def test_allocator_churn_conservation_and_cow(ops, page_tokens):
    """Arbitrary reserve/extend/shrink/share/fork/release churn against a
    mirror model: used + free == total always, a live-referenced page is
    never on the free list, fork copies exactly when shared, and a full
    release drains back to the free-block baseline."""
    a = PageAllocator(n_pages=24, page_tokens=page_tokens)
    mirror = {}
    for code, owner, n in ops:
        if code == "reserve":
            if owner in mirror or not a.can_reserve(n):
                with pytest.raises((KeyError, MemoryError)):
                    a.reserve(owner, n)
            else:
                mirror[owner] = a.reserve(owner, n)
        elif code == "extend":
            if owner not in mirror:
                with pytest.raises(KeyError):
                    a.extend(owner, n)
            else:
                need = blocks_for(n, page_tokens) - len(mirror[owner])
                if need > a.free_blocks:
                    with pytest.raises(MemoryError):
                        a.extend(owner, n)
                else:
                    mirror[owner] = mirror[owner] + a.extend(owner, n)
        elif code == "shrink":
            if owner not in mirror:
                with pytest.raises(KeyError):
                    a.shrink(owner, n)
            else:
                keep = blocks_for(n, page_tokens)
                expect = max(0, len(mirror[owner]) - keep)
                assert a.shrink(owner, n) == expect
                if expect:
                    mirror[owner] = mirror[owner][:-expect]
        elif code == "share":
            donors = sorted(mirror)
            if not donors:
                continue
            donor = donors[n % len(donors)]
            pages = mirror[donor][:1 + n % len(mirror[donor])]
            if owner in mirror:
                with pytest.raises(KeyError):
                    a.share(owner, pages)
            else:
                mirror[owner] = a.share(owner, pages)
        elif code == "fork":
            if owner not in mirror:
                with pytest.raises(KeyError):
                    a.fork(owner, 0)
                continue
            idx = n % len(mirror[owner])
            page = mirror[owner][idx]
            shared = sum(p == page for pages in mirror.values()
                         for p in pages) > 1
            if shared and a.free_blocks == 0:
                with pytest.raises(MemoryError):
                    a.fork(owner, idx)
            else:
                old, new = a.fork(owner, idx)
                assert old == page
                assert (new != old) == shared          # copy iff shared
                mirror[owner][idx] = new
        elif code == "release":
            if owner not in mirror:
                with pytest.raises(KeyError):
                    a.release(owner)
            else:
                assert a.release(owner) == len(mirror.pop(owner))
        _churn_check(a, mirror)
    for o in sorted(mirror):
        a.release(o)
    assert a.free_blocks == a.n_pages                  # baseline restored
    assert a.shared_blocks == 0 and a.owners() == []


# ---------------------------------------------------------------------------
# PrefixIndex (page-granular LCP lookup for the sharing join)
# ---------------------------------------------------------------------------
def test_prefix_index_lookup_matches_full_pages_only():
    from repro.kvcache import PrefixIndex
    idx = PrefixIndex(page_tokens=4)
    stream = np.arange(10, dtype=np.int32)             # 2 full pages + tail
    idx.insert(owner=1, tokens=stream, pages=[5, 6, 7])
    pages, hit = idx.lookup(np.arange(12, dtype=np.int32))
    assert pages == [5, 6] and hit == 8                # tail page not indexed
    pages, hit = idx.lookup(np.arange(6, dtype=np.int32))
    assert pages == [5] and hit == 4                   # partial second page
    pages, hit = idx.lookup(np.asarray([9, 9, 9, 9], np.int32))
    assert pages == [] and hit == 0                    # content mismatch


def test_prefix_index_deterministic_donor_and_removal():
    from repro.kvcache import PrefixIndex
    idx = PrefixIndex(page_tokens=4)
    stream = np.arange(8, dtype=np.int32)
    idx.insert(owner=9, tokens=stream, pages=[3, 4])
    idx.insert(owner=2, tokens=stream, pages=[6, 7])
    pages, hit = idx.lookup(stream)
    assert pages == [6, 7] and hit == 8                # min owner id wins
    idx.remove(2)
    pages, hit = idx.lookup(stream)
    assert pages == [3, 4] and hit == 8                # falls back to 9
    idx.remove(9)
    assert idx.lookup(stream) == ([], 0)               # trie pruned empty
    idx.remove(9)                                      # idempotent
