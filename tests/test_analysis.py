"""The static-analysis suite (repro.analysis): every rule must fire on
its known-bad fixture at the expected lines, stay silent on the good
twin, honor suppressions, and — the self-check — report the repo at
HEAD clean."""
import subprocess
import sys

import pytest

from repro.analysis import SourceFile, find_repo_root, run_analysis
from repro.analysis.framework import PASSES, all_rules
from repro.analysis.passes import (AllocatorPairingPass, ApiTypingPass,
                                   DeterminismPass, DocsRefsPass,
                                   ObsGuardPass, PallasConventionsPass)

REPO = find_repo_root()
FIX = REPO / "tests" / "analysis_fixtures"


def run_on(pass_cls, *paths, **attrs):
    """Run one pass over explicit files, applying the framework's
    suppression filter (as run_analysis would)."""
    pa = pass_cls()
    for k, v in attrs.items():
        setattr(pa, k, v)
    sfs = [SourceFile(REPO, p) for p in paths]
    by_rel = {sf.rel: sf for sf in sfs}
    return [f for f in pa.run(REPO, sfs)
            if not by_rel[f.path].suppressed(f.rule, f.line)]


def lines(findings):
    return sorted(f.line for f in findings)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_all_rules_registered():
    assert all_rules() == ["allocator-pairing", "api-typing", "determinism",
                           "docs-refs", "obs-guard", "pallas-conventions"]
    for name, cls in PASSES.items():
        assert cls.description, name


# ---------------------------------------------------------------------------
# allocator-pairing
# ---------------------------------------------------------------------------
def test_allocator_pairing_flags_cancel_leak_shapes():
    fs = run_on(AllocatorPairingPass, FIX / "allocator_pairing" / "bad.py")
    assert lines(fs) == [6, 13]
    assert all(f.rule == "allocator-pairing" for f in fs)
    # the PR 3 shape: reserve leaks via the early-return cancel path
    assert "reserve" in fs[0].message
    # release on the normal path only: the exceptional exit still leaks
    assert "exception" in fs[1].message


def test_allocator_pairing_accepts_paired_blessed_and_transfer():
    assert run_on(AllocatorPairingPass,
                  FIX / "allocator_pairing" / "good.py") == []


# ---------------------------------------------------------------------------
# obs-guard
# ---------------------------------------------------------------------------
def test_obs_guard_flags_unguarded_hooks():
    fs = run_on(ObsGuardPass, FIX / "obs_guard" / "bad.py")
    assert lines(fs) == [6, 11, 15]
    # the guard must check the *same* chain as the call's receiver
    assert "self.core.obs" in fs[2].message


def test_obs_guard_accepts_every_guard_form():
    assert run_on(ObsGuardPass, FIX / "obs_guard" / "good.py") == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_determinism_flags_every_banned_construct():
    fs = run_on(DeterminismPass, FIX / "determinism" / "bad.py")
    assert lines(fs) == [9, 10, 11, 12, 13, 15, 17]
    text = " | ".join(f.message for f in fs)
    for needle in ("wall-clock", "global-RNG", "without a seed", "id()",
                   "unordered set", ".pop()"):
        assert needle in text, needle


def test_determinism_accepts_seeded_and_sorted_spellings():
    assert run_on(DeterminismPass, FIX / "determinism" / "good.py") == []


# ---------------------------------------------------------------------------
# pallas-conventions
# ---------------------------------------------------------------------------
def _pallas_run(subdir):
    d = FIX / subdir
    return run_on(PallasConventionsPass, *sorted(d.glob("*.py")),
                  kernels_dir=f"tests/analysis_fixtures/{subdir}")


def test_pallas_conventions_flags_all_five_contract_breaks():
    fs = _pallas_run("pallas_bad")
    text = " | ".join(f.message for f in fs)
    assert "not dispatched" in text                      # no ops.py import
    assert "no jnp oracle" in text                       # no badkernel_ref
    assert "mutable container" in text                   # index-map closure
    assert "key 5 is out of range" in text               # 2 operands only
    assert "value 3 is out of range" in text             # 1 output only
    assert "branches on traced value" in text            # if on x_ref value
    assert lines(fs) == [1, 7, 12, 13, 13, 19]


def test_pallas_conventions_accepts_conforming_kernel():
    assert _pallas_run("pallas_good") == []


# ---------------------------------------------------------------------------
# api-typing
# ---------------------------------------------------------------------------
def test_api_typing_flags_unannotated_defs():
    fs = run_on(ApiTypingPass, FIX / "api_typing" / "bad.py")
    assert lines(fs) == [4, 4, 9, 13]  # loose: params + return


def test_api_typing_accepts_annotations_and_exemptions():
    # __init__ return, annotated *vararg, and a header-line allow
    assert run_on(ApiTypingPass, FIX / "api_typing" / "good.py") == []


# ---------------------------------------------------------------------------
# docs-refs
# ---------------------------------------------------------------------------
def test_docs_refs_flags_dead_symbols_and_links():
    fs = run_on(DocsRefsPass, FIX / "docs_refs" / "bad.md")
    assert lines(fs) == [3, 4, 6]
    assert "no symbol" in fs[0].message
    assert "does not exist" in fs[1].message
    assert "broken link" in fs[2].message


def test_docs_refs_accepts_resolving_refs():
    assert run_on(DocsRefsPass, FIX / "docs_refs" / "good.md") == []


# ---------------------------------------------------------------------------
# suppression mechanics
# ---------------------------------------------------------------------------
def test_class_header_allow_covers_whole_body(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("class C:  # repro: allow(api-typing)\n"
                 "    def f(self, a):\n"
                 "        return a\n")
    assert run_on(ApiTypingPass, p) == []


def test_wildcard_allow_suppresses_any_rule(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("def f(a):  # repro: allow(*)\n    return a\n")
    assert run_on(ApiTypingPass, p) == []


def test_unsuppressed_twin_still_fires(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("def f(a):\n    return a\n")
    assert len(run_on(ApiTypingPass, p)) == 2


# ---------------------------------------------------------------------------
# self-check + CLI
# ---------------------------------------------------------------------------
def test_suite_is_clean_on_repo_at_head():
    """The acceptance bar: zero unsuppressed findings over the tree."""
    report = run_analysis(repo=REPO)
    assert report.ok, "\n" + report.render()
    assert report.n_files > 90  # really scanned the tree, not a subset


@pytest.mark.parametrize("argv,code,needle", [
    (["--list-rules"], 0, "allocator-pairing"),
    (["--all"], 0, "[repro.analysis] OK"),
    (["--rule", "nope"], 2, "unknown rule"),
])
def test_cli(argv, code, needle):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == code, proc.stdout + proc.stderr
    assert needle in proc.stdout + proc.stderr
