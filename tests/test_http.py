"""repro.serving.http: the stdlib OpenAI-compatible endpoint — routing,
non-streamed and SSE-streamed completions (one chunk per slice), and
429 + Retry-After from SLO-aware admission."""
import http.client
import json
import math

import pytest

from repro.serving import HTTPFrontend, ServingConfig
from repro.serving.http import _BadRequest, encode_prompt

SLICE = 8


@pytest.fixture(scope="module")
def frontend():
    server = ServingConfig(strategy="scls", workers=2, slice_len=SLICE,
                           gamma=0.25).build_sim()
    front = HTTPFrontend(server.aio, port=0, model_name="scls-sim").start()
    yield front
    front.shutdown()


def _request(front, method, path, body=None):
    conn = http.client.HTTPConnection(front.host, front.port, timeout=60)
    headers = {"Content-Type": "application/json"} if body is not None else {}
    conn.request(method, path,
                 json.dumps(body) if body is not None else None, headers)
    resp = conn.getresponse()
    raw = resp.read()
    conn.close()
    return resp, raw


def test_healthz_and_models(frontend):
    resp, raw = _request(frontend, "GET", "/healthz")
    assert resp.status == 200
    snap = json.loads(raw)
    assert snap["status"] == "ok" and snap["strategy"] == "SCLS"
    assert snap["backend"] == "SimBackend" and snap["workers"] == 2
    # live load signals from the repro.obs gauges (fleet-router inputs)
    assert isinstance(snap["queue_depth"], int) and snap["queue_depth"] >= 0
    assert isinstance(snap["in_flight_slices"], int)
    assert 0 <= snap["in_flight_slices"] <= snap["workers"]
    resp, raw = _request(frontend, "GET", "/v1/models")
    assert resp.status == 200
    assert json.loads(raw)["data"][0]["id"] == "scls-sim"


def test_completion_non_streamed(frontend):
    resp, raw = _request(frontend, "POST", "/v1/completions",
                         {"model": "scls-sim",
                          "prompt": "tell me about slice level scheduling",
                          "max_tokens": 20})
    assert resp.status == 200
    out = json.loads(raw)
    assert out["object"] == "text_completion"
    choice = out["choices"][0]
    assert choice["finish_reason"] == "length"
    assert [int(t) for t in choice["text"].split()] == list(range(20))
    assert out["usage"] == {"prompt_tokens": 6, "completion_tokens": 20,
                            "total_tokens": 26}


def test_sse_emits_one_chunk_per_completed_slice(frontend):
    """Tentpole acceptance: stream=true produces >= 1 SSE chunk per
    completed slice (here: exactly one per slice, since slice boundaries
    are recorded as they happen) and terminates with [DONE]."""
    max_tokens = 40
    resp, raw = _request(frontend, "POST", "/v1/completions",
                         {"prompt": "stream this", "max_tokens": max_tokens,
                          "stream": True})
    assert resp.status == 200
    assert resp.getheader("Content-Type") == "text/event-stream"
    events = [e[len("data: "):] for e in raw.decode().split("\n\n")
              if e.startswith("data: ")]
    assert events[-1] == "[DONE]"
    final = json.loads(events[-2])
    assert final["choices"][0]["finish_reason"] == "length"
    content = [json.loads(e) for e in events[:-2]]
    n_slices = math.ceil(max_tokens / SLICE)
    assert len(content) >= n_slices
    toks = [int(t) for c in content for t in c["choices"][0]["text"].split()]
    assert toks == list(range(max_tokens))
    # chunk boundaries are slice boundaries: no chunk exceeds one slice
    assert all(len(c["choices"][0]["text"].split()) <= SLICE
               for c in content)


def test_unmeetable_slo_rejected_with_429_before_any_work(frontend):
    core = frontend.aserver.core
    n_requests_before = len(core.requests)
    batches_before = len(core.batch_log)
    resp, raw = _request(frontend, "POST", "/v1/completions",
                         {"prompt": 512, "max_tokens": 900, "slo_ms": 1})
    assert resp.status == 429
    assert int(resp.getheader("Retry-After")) >= 1
    err = json.loads(raw)["error"]
    assert err["type"] == "rate_limit_exceeded"
    assert "deadline" in err["message"]
    # nothing entered the scheduler
    assert len(core.requests) == n_requests_before
    assert len(core.batch_log) == batches_before
    resp, raw = _request(frontend, "GET", "/metrics.json")
    m = json.loads(raw)
    assert m["n_rejected"] >= 1
    assert m["reject_reasons"].get("deadline", 0) >= 1  # per-reason counts


def test_healthz_exports_fleet_placement_vector(frontend):
    """The fleet router's InstanceSnapshot parses these fields
    (repro.fleet.registry) — the Eq. 10–11 load terms plus residency."""
    resp, raw = _request(frontend, "GET", "/healthz")
    snap = json.loads(raw)
    assert len(snap["worker_loads"]) == snap["workers"]
    assert all(isinstance(x, float) and x >= 0
               for x in snap["worker_loads"])
    assert snap["min_load"] == min(snap["worker_loads"])
    assert isinstance(snap["queue_delay_est"], float)
    assert snap["queue_delay_est"] >= snap["min_load"]
    assert snap["n_sessions"] == 0       # sim backend anchors nothing
    assert snap["shared_blocks"] == 0
    # the admission counters ride along (cumulative placement inputs)
    assert snap["n_submitted"] >= 0 and snap["n_rejected"] >= 0


def test_paced_retry_after_keeps_subsecond_hints():
    """Regression: a paced (time_scale) run maps the core-seconds retry
    hint through the same virtual->wall scaling as submissions — a
    sub-second wall hint must not be floored up to 1s."""
    server = ServingConfig(strategy="scls", workers=2, slice_len=SLICE,
                           gamma=0.25, time_scale=1000.0).build_sim()
    front = HTTPFrontend(server.aio, port=0).start()
    try:
        resp, raw = _request(front, "POST", "/v1/completions",
                             {"prompt": 512, "max_tokens": 900,
                              "slo_ms": 1})
        assert resp.status == 429
        ra = float(resp.getheader("Retry-After"))
        assert 0 < ra < 1       # ~60 core-s backlog / 1000x pacing
    finally:
        front.shutdown()


def test_meetable_slo_accepted(frontend):
    resp, raw = _request(frontend, "POST", "/v1/completions",
                         {"prompt": "quick one", "max_tokens": 8,
                          "slo_ms": 600_000})
    assert resp.status == 200
    assert json.loads(raw)["usage"]["completion_tokens"] == 8


def test_bad_requests_get_400_not_500(frontend):
    for body in ({}, {"prompt": "x", "max_tokens": 0},
                 {"prompt": "x", "max_tokens": "lots"},
                 {"prompt": True}, {"prompt": []},
                 {"prompt": "x", "slo_ms": -5}):
        resp, raw = _request(frontend, "POST", "/v1/completions", body)
        assert resp.status == 400, body
        assert json.loads(raw)["error"]["type"] == "invalid_request_error"
    resp, _ = _request(frontend, "GET", "/nope")
    assert resp.status == 404
    # /v1/chat/completions exists since PR 7: a completions-style body
    # (no messages) is malformed for it, not an unknown route
    resp, raw = _request(frontend, "POST", "/v1/chat/completions",
                         {"prompt": "x"})
    assert resp.status == 400
    assert json.loads(raw)["error"]["type"] == "invalid_request_error"


def test_metrics_json_endpoint_reports_run_metrics(frontend):
    resp, raw = _request(frontend, "GET", "/metrics.json")
    assert resp.status == 200
    m = json.loads(raw)
    for key in ("n_completed", "throughput", "ttft_mean", "p99_response",
                "slo_attainment", "n_rejected", "n_submitted",
                "reprefill_tokens",   # §3.3 overhead, first-class (PR 5)
                "n_rejected_memory", "n_rejected_deadline"):  # repro.obs
        assert key in m
    assert m["n_completed"] >= 1


def test_metrics_endpoint_serves_prometheus_text(frontend):
    """/metrics is the Prometheus exposition now (scrape-ready); the
    legacy JSON dump moved to /metrics.json."""
    resp, raw = _request(frontend, "GET", "/metrics")
    assert resp.status == 200
    assert resp.getheader("Content-Type").startswith("text/plain")
    assert "version=0.0.4" in resp.getheader("Content-Type")
    text = raw.decode()
    assert _validate_obs().validate_prometheus(text) == []
    fams = _validate_obs().parse_prometheus(text)
    # the serving instruments observed the traffic earlier tests drove
    assert fams["scls_slices_dispatched_total"]["samples"][
        "scls_slices_dispatched_total"] >= 1
    assert fams["scls_requests_total"]["type"] == "counter"
    assert any(k.startswith("scls_ttft_seconds_bucket")
               for k in fams["scls_ttft_seconds"]["samples"])
    # per-verdict admission counts (the 429 test rejected one)
    assert fams["scls_admission_total"]["samples"][
        'scls_admission_total{action="reject",reason="deadline"}'] >= 1


def test_debug_decisions_endpoint(frontend):
    resp, raw = _request(frontend, "GET", "/debug/decisions")
    assert resp.status == 200
    out = json.loads(raw)
    assert out["enabled"] and out["n_recorded"] >= 1
    kinds = {e["kind"] for e in out["events"]}
    assert kinds <= {"admission", "batch", "offload"}
    assert {"batch", "offload"} <= kinds  # traffic was dispatched above
    # kind + limit filters
    resp, raw = _request(frontend, "GET", "/debug/decisions?kind=batch&n=2")
    batches = json.loads(raw)["events"]
    assert len(batches) <= 2
    assert all(e["kind"] == "batch" for e in batches)
    # rid filter returns only that request's decisions
    rid = batches[-1]["rids"][0]
    resp, raw = _request(frontend, "GET", f"/debug/decisions?rid={rid}")
    mine = json.loads(raw)["events"]
    assert mine and all(e.get("rid") == rid or rid in e.get("rids", [])
                        for e in mine)
    # malformed query ints are a 400, not a 500
    resp, _ = _request(frontend, "GET", "/debug/decisions?rid=abc")
    assert resp.status == 400


def _validate_obs():
    import importlib.util
    import pathlib
    spec = importlib.util.spec_from_file_location(
        "validate_obs_http",
        pathlib.Path(__file__).parent.parent / "scripts" / "validate_obs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_encode_prompt_shapes():
    assert encode_prompt("three word prompt", 0) == {"input_len": 3}
    assert encode_prompt(17, 0) == {"input_len": 17}
    # with a real vocabulary an integer prompt must synthesize actual
    # token ids (a real backend cannot run prompt=None)
    filler = encode_prompt(7, 100)["prompt"]
    assert filler.shape == (7,) and 0 <= filler.min() <= filler.max() < 100
    out = encode_prompt("hash these words", 1000)
    assert out["prompt"].shape == (3,) and out["prompt"].max() < 1000
    ids = encode_prompt([5, 6, 7], 4)["prompt"]
    assert list(ids) == [1, 2, 3]  # wrapped into the vocabulary
    with pytest.raises(_BadRequest):
        encode_prompt(0, 0)
    with pytest.raises(_BadRequest):
        encode_prompt([1, "a"], 0)
    with pytest.raises(_BadRequest):
        encode_prompt({"not": "supported"}, 0)
