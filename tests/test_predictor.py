"""Tests for the generation-length prediction subsystem (repro.predict):
calibration coverage, histogram convergence, and the simulator end-to-end
ordering SCLS <= SCLS-PRED <= ORACLE (with SCLS-PRED + PerfectPredictor
identical to ORACLE by construction)."""
import copy

import numpy as np
import pytest

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import CODEFUSE, SHAREGPT, generate_trace
from repro.core.batcher import bucketed_pred_batch
from repro.core.estimator import ServingTimeEstimator, a100_llama13b_profile
from repro.core.memory import AnalyticMemoryEstimator, LLAMA2_13B_DELTA
from repro.core.request import Request
from repro.core.schedulers import make_strategy
from repro.predict import (HistogramPredictor, PerfectPredictor,
                           QuantileCalibrator, make_predictor)


def _completed(rid, total, input_len=8):
    """A finished request: ``generated`` holds the realized total length."""
    return Request(rid=rid, arrival=0.0, input_len=input_len, gen_len=total,
                   generated=total)


def _lognormal_totals(n, mu=4.6, sigma=1.0, max_gen=1024, seed=0):
    rng = np.random.default_rng(seed)
    return np.clip(np.round(rng.lognormal(mu, sigma, n)), 1, max_gen).astype(int)


# ---------------------------------------------------------------------------
# predictors
# ---------------------------------------------------------------------------
def test_perfect_predictor_reads_ground_truth():
    p = PerfectPredictor()
    r = Request(rid=0, arrival=0.0, input_len=4, gen_len=300, generated=100)
    assert p.predict_remaining(r) == 200.0


def test_histogram_predictor_converges_to_quantiles():
    """Trained on a lognormal stream, the histogram's raw predictions hit
    their target quantile on held-out data (unconditional and conditional)."""
    totals = _lognormal_totals(4000)
    train, held = totals[:2000], totals[2000:]
    for q in (0.5, 0.7, 0.9):
        h = HistogramPredictor(max_gen=1024, quantile=q)
        for i, t in enumerate(train):
            h.observe(_completed(i, int(t)))
        cov0 = np.mean(held <= h.predict_total(0))
        assert abs(cov0 - q) < 0.07, (q, cov0)
        survivors = held[held > 128]
        cov128 = np.mean(survivors <= h.predict_total(128))
        assert abs(cov128 - q) < 0.07, (q, cov128)


def test_histogram_conditional_hazard_adapts():
    """Having survived g tokens must raise the predicted total (lognormal
    hazard: long requests keep going)."""
    h = HistogramPredictor(max_gen=1024, quantile=0.5)
    for i, t in enumerate(_lognormal_totals(2000)):
        h.observe(_completed(i, int(t)))
    assert h.predict_total(256) > h.predict_total(64) > h.predict_total(0)


def test_histogram_cold_start_falls_back_to_max_gen():
    h = HistogramPredictor(max_gen=512, min_observed=8)
    r = Request(rid=0, arrival=0.0, input_len=4, gen_len=10)
    # under-trained: predict the full budget so scls-pred degrades to scls
    assert h.predict_remaining(r) == 512.0


def test_histogram_censored_evidence_counts():
    """In-flight requests contribute survival mass: a stream of completions
    at 64 plus many still-running requests past 512 must push the median
    prediction above the completions-only answer."""
    biased = HistogramPredictor(max_gen=1024, quantile=0.5)
    debiased = HistogramPredictor(max_gen=1024, quantile=0.5)
    for i in range(50):
        biased.observe(_completed(i, 64))
        debiased.observe(_completed(i, 64))
    for i in range(50):  # long requests, still generating
        alive = Request(rid=1000 + i, arrival=0.0, input_len=4,
                        gen_len=1024, generated=512)
        debiased.observe_alive(alive)
    assert debiased.predict_total(0) > biased.predict_total(0)


def test_proxy_predictor_trains_online():
    proxy = make_predictor("proxy", max_gen=1024)
    rng = np.random.default_rng(0)
    totals = _lognormal_totals(300, seed=3)
    for i, t in enumerate(totals):
        r = _completed(i, int(t), input_len=int(rng.integers(4, 64)))
        proxy.observe(r)
    fresh = Request(rid=9999, arrival=0.0, input_len=16, gen_len=100)
    pred = proxy.predict_remaining(fresh)
    # learned the scale of the marginal (median ~100): order of magnitude,
    # not the cold-start extremes
    assert 20.0 <= pred <= 600.0


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------
def test_calibration_coverage_on_heldout_lognormal():
    """Calibrated caps achieve >= target coverage on held-out traffic even
    when the raw predictor is biased low (here: a constant under-guess)."""

    class HalfMedian(HistogramPredictor):
        def predict_remaining(self, req):
            return 50.0  # systematically under-predicts (true median ~100)

    for target in (0.6, 0.8):
        pred = HalfMedian(max_gen=1024)
        calib = QuantileCalibrator(coverage=target, window=2000)
        totals = _lognormal_totals(3000, seed=1)
        caps = []
        for i, t in enumerate(totals):
            r = Request(rid=i, arrival=0.0, input_len=8, gen_len=int(t))
            caps.append((calib.cap(r, pred.predict_remaining(r)), int(t), i))
            r.generated = int(t)
            calib.observe(r)
        # held-out = second half (scale has converged by then)
        hits = [c >= t for c, t, i in caps[1500:]]
        cov = float(np.mean(hits))
        assert cov >= target - 0.05, (target, cov)
        assert calib.scale > 1.0  # it actually corrected the bias


def test_calibration_is_identity_for_perfect_predictions():
    pred = PerfectPredictor()
    calib = QuantileCalibrator(coverage=0.9)
    totals = _lognormal_totals(500, seed=2)
    for i, t in enumerate(totals):
        r = Request(rid=i, arrival=0.0, input_len=8, gen_len=int(t))
        cap = calib.cap(r, pred.predict_remaining(r))
        assert cap == int(t)  # caps pass through exactly
        r.generated = int(t)
        calib.observe(r)
    assert calib.scale == pytest.approx(1.0)


def test_calibration_scores_every_prediction_point():
    calib = QuantileCalibrator(coverage=0.5)
    r = Request(rid=0, arrival=0.0, input_len=8, gen_len=300)
    calib.cap(r, 10.0)     # under-prediction at g=0
    r.generated = 100
    calib.cap(r, 200.0)    # exact at g=100
    r.generated = 300
    calib.observe(r)
    assert len(calib.ratios) == 2
    assert max(calib.ratios) == pytest.approx(30.0)  # 300 / 10


# ---------------------------------------------------------------------------
# prediction-aware batching
# ---------------------------------------------------------------------------
def _est():
    true_lat = a100_llama13b_profile()
    rng = np.random.default_rng(0)
    pre = [(N, L, true_lat.t_prefill(N, L)) for N in (1, 4, 16)
           for L in (16, 128, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N)) for N in (1, 4, 16)
           for L in (16, 128, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    return est


def test_bucketed_pred_batch_groups_and_caps():
    est = _est()
    mem = AnalyticMemoryEstimator(delta_bytes=1000.0, m_available=1e9)
    reqs = [Request(rid=i, arrival=0.0, input_len=32, gen_len=1000)
            for i in range(6)]
    caps = {0: 4, 1: 20, 2: 30, 3: 200, 4: 500, 5: 90}
    batches = bucketed_pred_batch(reqs, caps, 128, est, mem, min_slice=16)
    by_rid = {r.rid: b for b in batches for r in b.requests}
    # long-class requests (cap >= S) are served at exactly the SCLS slice
    assert by_rid[3].slice_len == 128 and by_rid[4].slice_len == 128
    # short-class slices never exceed S and respect the floor
    for rid in (0, 1, 2, 5):
        assert 16 <= by_rid[rid].slice_len <= 128
    # a short request's slice covers its own cap (no self-truncation)
    assert by_rid[5].slice_len >= 90
    # every request is scheduled exactly once
    assert sorted(r.rid for b in batches for r in b.requests) == list(range(6))


def test_bucketed_pred_batch_rejects_degenerate_phi():
    est = _est()
    mem = AnalyticMemoryEstimator(delta_bytes=1000.0, m_available=1e9)
    reqs = [Request(rid=0, arrival=0.0, input_len=32, gen_len=100)]
    with pytest.raises(ValueError, match="phi"):
        bucketed_pred_batch(reqs, {0: 4}, 128, est, mem, phi=1.0)


class _SliceFloorMem(AnalyticMemoryEstimator):
    """Pathologically NON-monotone in S: rejects any slice under 48 tokens.

    Every shipped estimator's Eq. 5–9 bound loosens as the slice shrinks;
    this one tightens instead, so a batch the DP admitted at the bucket cap
    can become infeasible after ``bucketed_pred_batch`` shrinks the slice
    to the batch's own largest cap."""

    def fits(self, N, L_i, S):
        return S >= 48 and super().fits(N, L_i, S)


def test_bucketed_pred_batch_rechecks_bound_after_slice_shrink():
    """Regression: the post-shrink Eq. 5–9 bound is re-evaluated at the
    FINAL slice length, so a non-monotone estimator fails loudly instead
    of shipping a batch that was only checked at the looser bucket cap."""
    est = _est()
    # 1.5e6 bytes: the long request fits alone but not paired, forcing the
    # DP to split the bucket; the short batch then shrinks 60 -> 20, below
    # the pathological 48-token floor.
    mem = _SliceFloorMem(delta_bytes=1000.0, m_available=1.5e6)
    reqs = [Request(rid=0, arrival=0.0, input_len=10, gen_len=1000),
            Request(rid=1, arrival=0.0, input_len=1024, gen_len=1000)]
    with pytest.raises(RuntimeError, match="no longer"):
        bucketed_pred_batch(reqs, {0: 20, 1: 60}, 128, est, mem,
                            phi=8.0, min_slice=16)


def test_bucketed_pred_batch_envelope_packing_threads_through():
    """packing='envelope' reaches the inner dp_batch calls, and every
    returned batch satisfies the envelope bound at its final slice."""
    from repro.core.batcher import batch_fits
    from repro.core.memory import PagedMemoryEstimator
    est = _est()
    mem = PagedMemoryEstimator(delta_bytes=1000.0, m_available=1e7,
                               page_tokens=16)
    reqs = [Request(rid=i, arrival=0.0, input_len=32 + 100 * i, gen_len=1000)
            for i in range(6)]
    caps = {0: 4, 1: 20, 2: 30, 3: 200, 4: 500, 5: 90}
    batches = bucketed_pred_batch(reqs, caps, 128, est, mem, min_slice=16,
                                  packing="envelope")
    assert sorted(r.rid for b in batches for r in b.requests) == list(range(6))
    for b in batches:
        assert batch_fits(b, mem, "envelope")


# ---------------------------------------------------------------------------
# end-to-end: the SCLS -> SCLS-PRED -> ORACLE ladder
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def pred_env():
    true_lat = a100_llama13b_profile()
    rng = np.random.default_rng(0)
    pre = [(N, L, true_lat.t_prefill(N, L) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    return true_lat, est


def _run_pred(pred_env, name, trace, duration, **kw):
    true_lat, est = pred_env
    # memory-constrained regime: KV capacity binds the batch size, so
    # length knowledge pays (the S³ setting)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=5e9, zeta=0.9)
    s = make_strategy(name, slice_len=128, gamma=3.0, coverage=0.7, **kw)
    sim = ClusterSimulator(s, 4, true_lat, est, mem, seed=2)
    return sim.run(copy.deepcopy(trace), duration).metrics


@pytest.mark.parametrize("spec", [CODEFUSE, SHAREGPT], ids=lambda s: s.name)
def test_scls_pred_between_scls_and_oracle(pred_env, spec):
    """Acceptance ladder on both paper workloads: the online histogram
    predictor lands strictly between length-blind SCLS and the perfect
    ORACLE, with fewer invalid tokens than SCLS."""
    trace = generate_trace(24.0, 120.0, spec, seed=1)
    scls = _run_pred(pred_env, "scls", trace, 120.0)
    pred = _run_pred(pred_env, "scls-pred", trace, 120.0)
    oracle = _run_pred(pred_env, "oracle", trace, 120.0)
    assert scls.n_completed == scls.n_requests
    assert pred.n_completed == pred.n_requests
    assert oracle.n_completed == oracle.n_requests
    assert scls.throughput < pred.throughput < oracle.throughput
    assert pred.avg_invalid_tokens < scls.avg_invalid_tokens
    assert oracle.avg_invalid_tokens < scls.avg_invalid_tokens


def test_perfect_predictor_reproduces_oracle(pred_env):
    """ORACLE is literally scls-pred + PerfectPredictor: identical runs."""
    trace = generate_trace(12.0, 60.0, CODEFUSE, seed=3)
    oracle = _run_pred(pred_env, "oracle", trace, 60.0)
    perfect = _run_pred(pred_env, "scls-pred", trace, 60.0,
                        predictor="perfect")
    assert perfect.throughput == pytest.approx(oracle.throughput)
    assert perfect.avg_invalid_tokens == pytest.approx(
        oracle.avg_invalid_tokens)


def test_predictor_feedback_loop_runs(pred_env):
    """The simulator trains the predictor online: after a run the histogram
    has seen every completed request and calibration has scored them."""
    true_lat, est = pred_env
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=5e9, zeta=0.9)
    trace = generate_trace(6.0, 60.0, CODEFUSE, seed=4)
    s = make_strategy("scls-pred", slice_len=128, gamma=3.0)
    sim = ClusterSimulator(s, 2, true_lat, est, mem, seed=5)
    res = sim.run(copy.deepcopy(trace), 60.0)
    assert sim.predictor.n_observed == res.metrics.n_completed
    assert len(sim.calibrator.ratios) > 0
    assert np.isfinite(sim.calibrator.empirical_coverage())
