"""Cluster-level tests: simulator invariants, paper-claim ordering, trace
properties, and the real-execution cluster."""
import copy

import numpy as np
import pytest

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import (CODEFUSE, SHAREGPT, generate_trace,
                                 length_distribution_summary)
from repro.core.estimator import ServingTimeEstimator, a100_llama13b_profile
from repro.core.memory import (A100_80GB_AVAILABLE, AnalyticMemoryEstimator,
                               LLAMA2_13B_DELTA)
from repro.core.request import Request
from repro.core.schedulers import make_strategy


@pytest.fixture(scope="module")
def sim_env():
    true_lat = a100_llama13b_profile()
    rng = np.random.default_rng(0)
    pre = [(N, L, true_lat.t_prefill(N, L) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    dec = [(N, L, true_lat.tau_decode(L, N) * rng.lognormal(0, 0.02))
           for N in (1, 2, 4, 8, 16, 32) for L in (16, 128, 512, 1024)]
    est, _, _ = ServingTimeEstimator.fit(pre, dec)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=A100_80GB_AVAILABLE, zeta=0.9)
    return true_lat, est, mem


def run(name, sim_env, rate=24.0, duration=120.0, workers=4, **kw):
    true_lat, est, mem = sim_env
    trace = generate_trace(rate, duration, CODEFUSE, seed=1)
    s = make_strategy(name, slice_len=128, fixed_batch_size=12, gamma=3.0, **kw)
    sim = ClusterSimulator(s, workers, true_lat, est, mem, seed=2)
    return sim.run(copy.deepcopy(trace), duration).metrics


def test_trace_matches_fig6_shape():
    t = generate_trace(20, 300, CODEFUSE, seed=0)
    s = length_distribution_summary(t)
    assert s["frac_lt_512"] > 0.9  # "vast majority < 512" (Fig. 6)
    assert s["gen_p50"] < 200
    t2 = generate_trace(20, 300, SHAREGPT, seed=0)
    assert length_distribution_summary(t2)["frac_lt_512"] > 0.8


def test_all_requests_complete_under_every_strategy(sim_env):
    for name in ("sls", "ils", "so", "pm", "ab", "lb", "scls"):
        m = run(name, sim_env, rate=2.0, duration=60.0, workers=2)
        assert m.n_completed == m.n_requests, name


def test_scls_beats_sls_and_ils_throughput(sim_env):
    """Headline claim (Fig. 12): SCLS > ILS > SLS in throughput; response
    times the other way around."""
    sls = run("sls", sim_env)
    ils = run("ils", sim_env)
    scls = run("scls", sim_env)
    assert scls.throughput > ils.throughput > sls.throughput
    assert scls.mean_response < sls.mean_response
    assert scls.p95_response < sls.p95_response


def test_ablation_chain_monotone(sim_env):
    """Fig. 15: each added SCLS feature should not hurt throughput much and
    the full chain must improve substantially over SO."""
    so = run("so", sim_env)
    ab = run("ab", sim_env)
    scls = run("scls", sim_env)
    assert ab.throughput > so.throughput
    assert scls.throughput >= ab.throughput * 0.95
    assert scls.throughput > so.throughput * 1.3


def test_slicing_reduces_invalid_tokens(sim_env):
    """Fig. 13a/16a: generation slicing slashes invalid tokens."""
    sls = run("sls", sim_env)
    so = run("so", sim_env)
    assert so.avg_invalid_tokens < sls.avg_invalid_tokens * 0.5


def test_adaptive_batching_increases_batch_size(sim_env):
    """Fig. 13b/16b: lifting the fixed cap grows batch sizes."""
    pm = run("pm", sim_env)
    ab = run("ab", sim_env)
    assert ab.avg_batch_size > pm.avg_batch_size


def test_maxmin_improves_load_balance_at_moderate_load(sim_env):
    """Fig. 17: SCLS balances load far better than round-robin SLS."""
    sls = run("sls", sim_env, rate=10.0, duration=240.0)
    scls = run("scls", sim_env, rate=10.0, duration=240.0)
    assert scls.ct_std < sls.ct_std


def test_early_return_ratio_small_for_scls(sim_env):
    """Fig. 14b: < a few percent of batches return early at S=128."""
    m = run("scls", sim_env)
    assert m.early_return_ratio < 0.05


def test_most_requests_finish_in_few_slices(sim_env):
    """Fig. 14a: vast majority of requests need <= 3 schedules at S=128."""
    true_lat, est, mem = sim_env
    trace = generate_trace(8.0, 120.0, CODEFUSE, seed=1)
    s = make_strategy("scls", slice_len=128)
    sim = ClusterSimulator(s, 4, true_lat, est, mem, seed=2)
    res = sim.run(trace, 120.0)
    sched = np.array([r.n_schedules for r in res.requests if r.done])
    assert np.mean(sched <= 3) > 0.85


def test_scalability_linear_in_workers(sim_env):
    """Fig. 22: throughput grows ~linearly with worker count (saturated)."""
    m2 = run("scls", sim_env, rate=30.0, duration=120.0, workers=2)
    m4 = run("scls", sim_env, rate=30.0, duration=120.0, workers=4)
    assert m4.throughput > m2.throughput * 1.6


def test_simulator_conservation(sim_env):
    """No request is lost or duplicated; token accounting is consistent."""
    true_lat, est, mem = sim_env
    trace = generate_trace(5.0, 60.0, CODEFUSE, seed=3)
    s = make_strategy("scls", slice_len=64)
    sim = ClusterSimulator(s, 3, true_lat, est, mem, seed=1)
    res = sim.run(trace, 60.0)
    for r in res.requests:
        assert r.done
        assert r.generated == min(r.gen_len, r.max_gen)
        assert r.n_schedules >= 1
        assert r.finish_time >= r.arrival


def test_scls_cb_beyond_paper_beats_both(sim_env):
    """Beyond-paper (paper §7): slice leases on continuous batching should
    dominate both plain SCLS (no padding/invalid tokens) and ILS (no
    conservative cap, max-min placement)."""
    ils = run("ils", sim_env)
    scls = run("scls", sim_env)
    cb = run("scls-cb", sim_env)
    assert cb.throughput > scls.throughput > ils.throughput
    assert cb.mean_response < scls.mean_response
    assert cb.ct_std < scls.ct_std
    assert cb.avg_invalid_tokens == 0.0 and cb.avg_pad_tokens == 0.0


def test_oracle_upper_bounds_scls(sim_env):
    """Beyond-paper: ORACLE is SCLS-PRED with a perfect length predictor —
    slice-aware bucketed batching (repro.predict), not one-shot full-run
    batches.  Requests predicted to outlive a slice are scheduled exactly
    like SCLS, so perfect knowledge can only help: it upper-bounds SCLS
    and slashes invalid tokens (exact last slices)."""
    oracle = run("oracle", sim_env)
    scls = run("scls", sim_env)
    assert oracle.n_completed == oracle.n_requests
    assert oracle.throughput > scls.throughput
    assert oracle.avg_invalid_tokens < scls.avg_invalid_tokens * 0.5


def test_more_work_expected_sees_leased_out_requests(sim_env):
    """Regression: the tick-continuation check must count requests leased
    to continuous-mode workers (pending/running), not only queued batches
    and busy flags — otherwise a central tick strategy can terminate with
    work still checked out."""
    true_lat, est, mem = sim_env
    s = make_strategy("scls-cb", slice_len=64)
    sim = ClusterSimulator(s, 2, true_lat, est, mem, seed=0)
    r = Request(rid=0, arrival=0.0, input_len=8, gen_len=32)
    assert not sim._more_work_expected()  # idle cluster
    sim.workers[0].pending.append(r)
    assert sim._more_work_expected()      # leased but not yet running
    sim.workers[0].pending.clear()
    sim.workers[0].running.append([r, 8, 64])
    assert sim._more_work_expected()      # mid-lease
